"""The unified observability plane (`repro.obs`).

Covers the metrics registry (fixed-bucket histogram quantiles, collector
flattening, Prometheus rendering), the decision tracer (record chain,
Chrome-trace schema, validator negatives), the two invariants the plane
must never break — tracer-on vs tracer-off *bit-identical* decisions and
rng draws, and live block-walk verdicts agreeing with ``explain()``'s
rejection-reason vocabulary — plus the schema module (pool snapshot
bit-compat, per-zone pool residency, shard-router counters) and the
sharded route trace.
"""
import json
import random

import pytest

from repro.core.decision import REASON_MEMORY, REASON_WARMTH_TIER
from repro.obs import (
    LATENCY_BOUNDS_S,
    MetricsRegistry,
    Obs,
    StageTimers,
    Tracer,
    validate_chrome_trace,
)
from repro.obs.schema import POOL_SNAPSHOT_KEYS, pool_snapshot
from repro.platform import Platform
from repro.pool import StartCosts, WarmPool, make_policy

SCRIPT = """
d:
  workers: *
  strategy: best_first
  affinity: [!h]
i:
  - workers: *
    strategy: best_first
    affinity: [d]
  - followup: fail
h:
  workers: [w2]
"""


def _platform(**kw):
    kw.setdefault("cluster", {"w0": 8.0, "w1": 8.0, "w2": 8.0})
    plat = Platform.from_yaml(SCRIPT, **kw)
    plat.register("divide", memory=1.0, tag="d")
    plat.register("impera", memory=1.0, tag="i")
    plat.register("heavy", memory=4.0, tag="h")
    return plat


def _pool():
    return WarmPool(make_policy("fixed_ttl", ttl=100.0),
                    costs=StartCosts(cold=0.5, warm=0.1, hot=0.0),
                    budget_mb=64.0, hot_window=100.0)


# --------------------------------------------------------------------------- #
# metrics registry
# --------------------------------------------------------------------------- #


def test_histogram_quantiles_and_snapshot():
    reg = MetricsRegistry()
    h = reg.histogram("lat_s")
    for x in (0.001,) * 50 + (0.1,) * 45 + (5.0,) * 5:
        h.observe(x)
    snap = h.snapshot()
    assert snap["count"] == 100
    assert snap["sum"] == pytest.approx(0.001 * 50 + 0.1 * 45 + 25.0)
    # interpolated quantiles land within one quarter-decade bucket of truth
    assert 0.0003 < snap["p50"] <= 0.002
    assert 0.05 < snap["p95"] <= 0.2
    assert 1.0 < snap["p99"] <= 10.0
    assert h.quantile(1.0) >= snap["p99"]


def test_histogram_empty_and_overflow():
    h = MetricsRegistry().histogram("x")
    assert h.quantile(0.5) == 0.0
    assert h.snapshot()["count"] == 0
    h.observe(1e9)  # beyond the last bound: overflow bucket
    assert h.counts[-1] == 1
    assert h.quantile(0.5) == LATENCY_BOUNDS_S[-1]


def test_registry_snapshot_flattening_and_collector_replace():
    reg = MetricsRegistry()
    reg.counter("decisions").inc(3)
    reg.gauge("workers").set(7.0)
    reg.histogram("lat_s").observe(0.01)
    reg.register_collector("pool", lambda: {"cold": 1, "by_zone": {"eu": 2}})
    snap = reg.snapshot()
    assert snap["decisions"] == 3
    assert snap["workers"] == 7.0
    assert snap["lat_s.count"] == 1
    assert snap["pool.cold"] == 1
    assert snap["pool.by_zone.eu"] == 2  # nested dicts dot-join
    # re-registering a prefix replaces, never double-reports
    reg.register_collector("pool", lambda: {"cold": 9})
    snap = reg.snapshot()
    assert snap["pool.cold"] == 9
    assert "pool.by_zone.eu" not in snap


def test_registry_prometheus_render():
    reg = MetricsRegistry()
    reg.counter("sched.decisions").inc()
    reg.histogram("stage.mask_s").observe(0.001)
    text = reg.render()
    assert "# TYPE sched_decisions counter" in text
    assert "sched_decisions 1" in text
    # conformant histogram exposition: cumulative le-buckets + sum/count
    assert "# TYPE stage_mask_s histogram" in text
    assert 'stage_mask_s_bucket{le="0.001"} 1' in text
    assert 'stage_mask_s_bucket{le="+Inf"} 1' in text
    assert "stage_mask_s_sum 0.001" in text
    assert "stage_mask_s_count 1" in text


def test_registry_prometheus_render_golden(tmp_path):
    # a tiny registry with custom bounds, rendered against the checked-in
    # golden file — any exposition-format drift must be deliberate
    from pathlib import Path

    reg = MetricsRegistry()
    reg.counter("requests").inc(5)
    reg.gauge("inflight").set(2.0)
    h = reg.histogram("latency_s", bounds=(0.001, 0.01, 0.1, 1.0))
    for x in (0.0005, 0.005, 0.005, 0.05, 2.0):
        h.observe(x)
    reg.register_collector("pool", lambda: {"cold": 3, "rate": 0.5})
    # nested collector (the resilience bundle's snapshot shape): nested
    # dicts dot-join, dots become underscores in the exposition
    reg.register_collector("resilience", lambda: {
        "shed": 2, "queue_depth": 1,
        "tenants": {"gold": {"admitted": 4, "rate": 2}}})
    golden = Path(__file__).parent / "golden" / "metrics.prom"
    assert reg.render() == golden.read_text()


def test_stage_timers_sampling():
    reg = MetricsRegistry()
    tm = StageTimers(reg, sample=4)
    fired = [tm.sample() for _ in range(12)]
    assert fired == [False, False, False, True] * 3  # deterministic 1-in-4
    tm.observe("mask_build", 0.002)
    assert reg.histogram("sched.stage.mask_build_s").count == 1
    with pytest.raises(ValueError):
        StageTimers(reg, sample=3)  # not a power of two


# --------------------------------------------------------------------------- #
# tracer: records, exports, validator
# --------------------------------------------------------------------------- #


def test_tracer_record_chain_and_jsonl():
    tr = Tracer()
    d1 = tr.begin(1.0, "f", "eu")
    tr.blocks("f", 0, "w0")
    tr.decision(1.0, "f", "w0", "eu")
    tr.invoke("act-1", 1.0, "f", "w0", "warm", 0.1, "eu")
    tr.complete("act-1", 2.5)
    recs = tr.records()
    assert [r["kind"] for r in recs] == [
        "begin", "blocks", "decision", "invoke", "complete"]
    assert recs[0]["id"] == f"d{d1}"
    assert recs[3]["decision_id"] == f"d{d1}"
    assert recs[1]["t"] == 1.0  # blocks stamped with the begin-scope time
    lines = tr.to_jsonl().strip().splitlines()
    assert len(lines) == 5
    assert json.loads(lines[3])["start_kind"] == "warm"


def test_tracer_ring_bound():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.complete(f"act-{i}", float(i))
    assert len(tr.events) == 4
    assert tr.records()[0]["id"] == "act-6"  # oldest dropped first
    assert tr.dropped_spans == 6  # every eviction is counted, not silent


def test_tracer_dropped_spans_in_snapshot():
    obs = Obs(tracer=Tracer(capacity=4))
    for i in range(7):
        obs.tracer.complete(f"act-{i}", float(i))
    snap = obs.snapshot()
    assert snap["tracer.records"] == 4
    assert snap["tracer.dropped_spans"] == 3
    assert "tracer_dropped_spans 3" in obs.render()


def test_chrome_trace_layout():
    tr = Tracer()
    tr.begin(1.0, "f", "eu")
    tr.invoke("act-1", 1.0, "f", "eu0", "cold", 0.5, "eu")
    tr.complete("act-1", 3.0)
    tr.begin(4.0, "g")
    tr.invoke("act-2", 4.0, "g", "w9", "none", 0.0, None)
    ct = tr.chrome_trace()
    assert validate_chrome_trace(ct) == []
    evs = ct["traceEvents"]
    procs = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert procs == {"zone:eu", "zone:cluster"}
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == 1 and xs[0]["dur"] == pytest.approx(2e6)
    assert xs[0]["args"]["decision_id"] == "d1"
    # unmatched invoke renders as an instant, not a zero-length span
    assert any(e["ph"] == "i" and e["cat"] == "invoke" for e in evs)


def test_chrome_trace_validator_negatives():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [{"ph": "?", "name": "x"}]})
    bad_sort = {"traceEvents": [
        {"ph": "i", "name": "a", "ts": 5, "s": "t", "pid": 1, "tid": 0},
        {"ph": "i", "name": "b", "ts": 1, "s": "t", "pid": 1, "tid": 0}]}
    assert any("unsorted" in e for e in validate_chrome_trace(bad_sort))
    bad_dur = {"traceEvents": [
        {"ph": "X", "name": "a", "ts": 1, "dur": -2, "pid": 1, "tid": 0}]}
    assert any("dur" in e for e in validate_chrome_trace(bad_dur))
    unmatched = {"traceEvents": [
        {"ph": "B", "name": "a", "ts": 1, "pid": 1, "tid": 0}]}
    assert any("unclosed" in e for e in validate_chrome_trace(unmatched))
    ok = {"traceEvents": [
        {"ph": "B", "name": "a", "ts": 1, "pid": 1, "tid": 0},
        {"ph": "E", "name": "a", "ts": 2, "pid": 1, "tid": 0}]}
    assert validate_chrome_trace(ok) == []


# --------------------------------------------------------------------------- #
# invariant: tracing changes nothing
# --------------------------------------------------------------------------- #


def _drive(plat, n=40):
    rng = random.Random(7)
    mix = random.Random(11)
    out = []
    for _ in range(n):
        f = mix.choice(["divide", "impera", "heavy"])
        d = plat.invoke(f, rng)
        out.append((f, d.worker, d.start_kind))
        if d.worker is not None:
            plat.complete(d)
    # the rng's post-run stream is part of the fingerprint: a traced run
    # must consume exactly the same draws as an untraced one
    return out, [rng.random() for _ in range(3)]


def test_tracing_is_bit_identical():
    plain = _drive(_platform(pool=_pool()))
    traced_obs = Obs.enabled(verdicts=True)
    traced = _drive(_platform(pool=_pool(), obs=traced_obs))
    assert plain == traced
    assert len(traced_obs.tracer.events) > 0


def test_attach_detach_round_trip():
    obs = Obs.enabled()
    plat = _platform()
    plat.attach_obs(obs)
    plat.invoke("divide", random.Random(0))
    n = len(obs.tracer.events)
    assert n > 0
    plat.attach_obs(None)
    plat.invoke("divide", random.Random(0))
    assert len(obs.tracer.events) == n  # detached: nothing recorded
    plat.attach_obs(obs)
    plat.invoke("divide", random.Random(0))
    assert len(obs.tracer.events) > n


# --------------------------------------------------------------------------- #
# invariant: live block-walk verdicts agree with explain()
# --------------------------------------------------------------------------- #


def _assert_blocks_agree(blocks_rec, explained):
    walked = dict(blocks_rec["verdicts"])
    assert explained.trace is not None
    assert len(walked) == len(explained.trace)
    for bt in explained.trace:
        live = walked[bt.index]
        assert live == tuple(
            (v.worker, v.ok, v.reason) for v in bt.workers), (
            f"block {bt.index}: live trace disagrees with explain()")


def test_live_verdicts_agree_with_explain():
    obs = Obs.enabled(verdicts=True)
    plat = _platform(pool=_pool(), obs=obs)
    rng = random.Random(7)
    mix = random.Random(11)
    for _ in range(30):
        f = mix.choice(["divide", "impera", "heavy"])
        explained = plat.explain(f)
        d = plat.invoke(f, rng)
        rec = plat.obs.tracer.records()[-2 if d.worker else -1]
        if rec["kind"] != "blocks":  # unschedulable with no pool acquire
            rec = next(r for r in reversed(plat.obs.tracer.records())
                       if r["kind"] == "blocks")
        assert rec["function"] == f
        _assert_blocks_agree(rec, explained)
        assert rec["worker"] == explained.worker
        if d.worker is not None:
            plat.complete(d)


def test_live_verdicts_memory_and_warmth_reasons():
    obs = Obs.enabled(verdicts=True)
    plat = _platform(pool=_pool(), obs=obs)
    rng = random.Random(3)
    # fill w0..w2 until `heavy` (4.0) stops fitting somewhere: memory
    # rejections must surface in the live walk with explain()'s vocabulary
    live = []
    for _ in range(4):
        d = plat.invoke("heavy", rng)
        if d.worker:
            live.append(d)
    reasons = {v[2] for r in plat.obs.tracer.records()
               if r["kind"] == "blocks" and r["verdicts"]
               for _b, vs in r["verdicts"] for v in vs}
    assert REASON_MEMORY in reasons
    # warm the pool on one worker, then a warmth-tier drop appears once
    # another worker is also valid but colder
    for d in live:
        plat.complete(d)
    d1 = plat.invoke("divide", rng)
    plat.complete(d1)  # released: an idle `divide` container now warms d1.worker
    n = len(plat.obs.tracer.records())
    d2 = plat.invoke("divide", rng)
    assert d2.worker == d1.worker and d2.start_kind != "cold"
    reasons = {v[2] for r in plat.obs.tracer.records()[n:]
               if r["kind"] == "blocks" and r["verdicts"]
               for _b, vs in r["verdicts"] for v in vs}
    assert REASON_WARMTH_TIER in reasons


# --------------------------------------------------------------------------- #
# schema module + stats surfaces
# --------------------------------------------------------------------------- #


def test_pool_snapshot_schema_bit_compat():
    pool = _pool()
    plat = _platform(pool=pool)
    rng = random.Random(1)
    for _ in range(6):
        d = plat.invoke("divide", rng)
        if d.worker is not None:
            plat.complete(d)
    snap = pool.metrics.snapshot()
    assert tuple(snap.keys()) == POOL_SNAPSHOT_KEYS
    assert snap == pool_snapshot(pool.metrics)
    assert snap["total_starts"] >= 1


def test_pool_metrics_register_into():
    pool = _pool()
    reg = MetricsRegistry()
    pool.metrics.register_into(reg)
    assert reg.snapshot()["pool.cold_starts"] == 0


def test_platform_stats_zone_residency_and_router_counters():
    pool = WarmPool(make_policy("fixed_ttl", ttl=100.0),
                    costs=StartCosts(cold=0.5, warm=0.1, hot=0.0),
                    budget_mb=256.0, hot_window=100.0)
    plat = Platform(
        "t:\n  workers: *\n  topology: local_first\n",
        cluster={"eu0": 8.0, "eu1": 8.0, "us0": 8.0},
        zones={"eu0": "eu", "eu1": "eu", "us0": "us"},
        functions={"f": (1.0, "t")}, pool=pool)
    d = plat.invoke("f", zone="us")
    assert d.worker == "us0"
    plat.complete(d)
    stats = plat.stats()
    assert stats["zones"]["us"]["pool_idle"] == 1  # released container idles
    assert stats["zones"]["eu"]["pool_idle"] == 0
    assert stats["zone_masked"] == 0
    assert "pool" in stats and stats["pool"]["total_starts"] == 1
    plat.close()


def test_shard_router_route_trace_and_exhaustion_counter():
    obs = Obs.enabled()
    plat = Platform(
        "t:\n  workers: *\n  topology: local_first\n",
        cluster={"eu0": 1.0, "us0": 8.0},
        zones={"eu0": "eu", "us0": "us"},
        functions={"f": (4.0, "t")}, obs=obs)
    d = plat.invoke("f", zone="eu")  # does not fit in eu: spills to us
    assert d.worker == "us0"
    routes = [r for r in obs.tracer.records() if r["kind"] == "route"]
    assert len(routes) == 1
    r = routes[0]
    assert r["zone"] == "us" and r["hops"] >= 1
    assert any(z == "eu" for _b, z in r["tried"])  # eu tried first, exhausted
    assert plat.stats()["zone_exhausted"] >= 1
    plat.close()


def test_forecast_planner_action_counters():
    from repro.forecast import ArrivalForecast, ForecastPlanner, PlanConfig

    fc = ArrivalForecast(tau=5.0)
    pool = _pool()
    plat = _platform(pool=pool)
    planner = ForecastPlanner(fc, plat.compiled, plat.registry, PlanConfig())
    assert planner.stats["epochs"] == 0
    for t in range(20):
        fc.observe("divide", float(t))
    planner.plan(plat.state.conf(), pool, 20.0)
    assert planner.stats["epochs"] == 1
    assert set(planner.stats) == {"epochs", "prewarms", "migrations",
                                  "retires"}
