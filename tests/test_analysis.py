"""The v4 static-analysis subsystem: cost calculus + reachability.

Pins the acceptance criterion end-to-end: compiling the cold-start
benchmark's script against the paper testbed and its 512 MB keep-alive
budget must emit the chained scenario's ``budget-bound-colocation``
warning *at compile time*, naming the binding constraint.  Plus: the cost
calculus' arithmetic, chain closure, worker-shape normalisation,
deterministic report bytes (golden file, via ``Platform.verify()``), and
the service-time oracles.
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.core import CompileError, Registry, compile_script, parse
from repro.analysis import (
    AnalysisConfig,
    LifecycleCosts,
    RooflineOracle,
    TableOracle,
    WorkerShape,
    affinity_chain,
    analyze,
    as_worker_shapes,
)
from repro.cluster.topology import paper_testbed
from repro.workload import COMPUTE_S, register_functions

from benchmarks.coldstart import BUDGET_MB, SCRIPT as COLDSTART_SCRIPT

GOLDEN = Path(__file__).parent / "golden" / "verify_coldstart.txt"


def _reg():
    reg = Registry()
    register_functions(reg)
    return reg


# --------------------------------------------------------------------------- #
# the acceptance criterion: the chained scenario's 512 MB floor, statically
# --------------------------------------------------------------------------- #


def test_chained_scenario_colocation_flagged_at_compile_time():
    cs = compile_script(COLDSTART_SCRIPT, _reg(), workers=paper_testbed(),
                        budget_mb=BUDGET_MB, service_times=COMPUTE_S)
    assert cs.ir_version == 4
    assert len(cs.diagnostics) == 1
    d = cs.diagnostics[0]
    assert (d.severity, d.tag, d.code) == (
        "warning", "i", "budget-bound-colocation")
    # divide(256) + 2 x impera(192) = 640 > 512: the keep-alive budget is
    # the binding constraint (workers go up to 2048 MB), and the warning
    # must say so with the numbers
    assert "640 MB" in d.message
    assert "keep-alive budget = 512 MB" in d.message
    assert "capped at 1x" in d.message


def test_worker_memory_binds_when_budget_is_loose():
    # budget above the biggest worker: the constraint flips to worker memory
    report = analyze(parse(COLDSTART_SCRIPT), _reg(),
                     workers={"w0": 500.0}, budget_mb=4096.0)
    [d] = report.diagnostics
    assert d.code == "budget-bound-colocation"
    assert "worker memory = 500 MB" in d.message
    # and with room for the full fan-out there is nothing to say
    assert analyze(parse(COLDSTART_SCRIPT), _reg(),
                   workers={"w0": 2048.0}, budget_mb=2048.0).ok


# --------------------------------------------------------------------------- #
# cost calculus arithmetic
# --------------------------------------------------------------------------- #

COSTED = """
d:
  workers: *
  cost:
    - budget 0.6s
i:
  - workers: *
    affinity: [d]
    cost:
      - budget 2.0s
      - rate 0.5 $/GB-s
  - followup: fail
"""


def test_cost_pass_derives_chain_worst_case_and_flags_over_budget():
    reg = Registry()
    reg.register("divide", memory=256.0, tag="d")
    reg.register("impera", memory=512.0, tag="i")
    report = analyze(parse(COSTED), reg,
                     service_times={"divide": 0.3, "impera": 1.5})
    rows = {t.tag: t for t in report.tags}
    # d: cold 0.5 + 0.3, warm 0.1 + 0.3; chain is itself
    assert rows["d"].cold_s == pytest.approx(0.8)
    assert rows["d"].warm_s == pytest.approx(0.4)
    assert rows["d"].chain == ("d",)
    # i: chain i->d, cold (0.5+1.5)+(0.5+0.3)=2.8, warm (0.1+1.5)+(0.1+0.3)=2.0
    assert rows["i"].chain == ("i", "d")
    assert rows["i"].chain_cold_s == pytest.approx(2.8)
    assert rows["i"].chain_warm_s == pytest.approx(2.0)
    # usd = GB x cold_s x rate = 0.5 x 2.0 x 0.5
    assert rows["i"].usd_per_invoke == pytest.approx(0.5)

    # d over budget (0.8 > 0.6) and i over budget (2.8 > 2.0), sorted by tag
    assert [(d.tag, d.code) for d in report.diagnostics] == [
        ("d", "over-budget"), ("i", "over-budget")]
    assert "exceeds budget 2s by 0.800s" in report.diagnostics[1].message


def test_affinity_chain_is_transitive_and_deterministic():
    s = parse("a:\n  workers: *\n  affinity: [b]\n"
              "b:\n  workers: *\n  affinity: [c, a]\n"
              "c:\n  workers: *\n")
    assert affinity_chain("a", s) == ("a", "b", "c")
    assert affinity_chain("c", s) == ("c",)


def test_lifecycle_defaults_mirror_the_warm_pool():
    from repro.pool import StartCosts

    life, costs = LifecycleCosts(), StartCosts()
    assert (life.cold, life.warm, life.hot) == (
        costs.cold, costs.warm, costs.hot)


# --------------------------------------------------------------------------- #
# oracles + worker shapes
# --------------------------------------------------------------------------- #


def test_roofline_oracle_takes_the_binding_term():
    o = RooflineOracle(peak_flops_s=100.0, peak_bytes_s=10.0,
                       table={"tiny": 0.25})
    o.add_counts("fn", flops=1000.0, bytes_=10.0)  # compute-bound: 10s
    assert o.service_s("fn") == pytest.approx(10.0)
    o.add_counts("io", flops=10.0, bytes_=1000.0)  # memory-bound: 100s
    assert o.service_s("io") == pytest.approx(100.0)
    assert o.service_s("tiny") == 0.25  # table fallback
    assert o.service_s("ghost") is None
    assert TableOracle({"x": 1.0}).service_s("x") == 1.0


def test_as_worker_shapes_normalises_and_sorts():
    shapes = as_worker_shapes({"b": 512, "a": paper_testbed()["workereu1"]})
    assert shapes == (WorkerShape("a", "eu", 1024.0),
                      WorkerShape("b", "", 512.0))
    assert as_worker_shapes(shapes) == shapes  # already-shaped passthrough
    with pytest.raises(TypeError):
        as_worker_shapes({"w": object()})


# --------------------------------------------------------------------------- #
# determinism + the golden verify report (Platform.verify path)
# --------------------------------------------------------------------------- #


def _platform():
    from repro.platform import Platform
    from repro.pool import StartCosts, WarmPool, make_policy

    testbed = paper_testbed()
    pool = WarmPool(make_policy("fixed_ttl", ttl=4.0),
                    costs=StartCosts(cold=0.5, warm=0.1, hot=0.0),
                    budget_mb=BUDGET_MB)
    plat = Platform.from_yaml(
        COLDSTART_SCRIPT,
        cluster={w.name: float(w.memory_mb) for w in testbed.values()},
        zones={w.name: w.zone for w in testbed.values()},
        pool=pool)
    register_functions(plat.registry)
    return plat


def test_platform_verify_matches_the_golden_report():
    report = _platform().verify(service_times=COMPUTE_S)
    assert report.format() == GOLDEN.read_text()


def test_report_is_deterministic_across_worker_orderings():
    reg = _reg()
    fwd = dict(sorted(paper_testbed().items()))
    rev = dict(sorted(paper_testbed().items(), reverse=True))
    a = analyze(parse(COLDSTART_SCRIPT), reg, workers=fwd,
                budget_mb=BUDGET_MB, service_times=COMPUTE_S)
    b = analyze(parse(COLDSTART_SCRIPT), reg, workers=rev,
                budget_mb=BUDGET_MB, service_times=COMPUTE_S)
    assert a.format() == b.format()
    assert a.diagnostics == b.diagnostics


def test_search_budget_exhaustion_stays_silent():
    # an absurdly small state budget: the search proves nothing, so the
    # pass must emit nothing (no unproven diagnostics, no false errors)
    report = analyze(parse(COLDSTART_SCRIPT), _reg(),
                     workers=paper_testbed(), budget_mb=BUDGET_MB,
                     config=AnalysisConfig(max_states=1))
    assert not any(d.code == "unplaceable-chain" for d in report.diagnostics)
