"""Forecast subsystem: estimators, planner validity/feasibility, the
predictive keep-alive policy, pool prewarm/migrate entry points, and the
end-to-end predictive simulator integration."""
import math
import random

import pytest

from repro.cluster.simulator import ClusterSim, SimParams
from repro.cluster.topology import paper_testbed, two_pod_cells
from repro.core import parse, try_schedule
from repro.core.scheduler import candidate_blocks, valid
from repro.core.state import ClusterState, Registry
from repro.forecast import (
    ArrivalForecast,
    ForecastPlanner,
    Migrate,
    PlanConfig,
    Prewarm,
    Retire,
    SeasonalProfile,
)
from repro.pool import (
    AffinityAwareKeepAlive,
    PredictiveKeepAlive,
    StartCosts,
    WarmPool,
    make_policy,
)
from repro.serve.engine import Engine, Request
from repro.workload import (
    COMPUTE_S,
    TraceWorkload,
    build_trace,
    register_functions,
)

AFFINE_SCRIPT = """
d:
  workers: *
  strategy: random
i:
  workers: *
  strategy: random
  affinity: [d]
"""


def _pool(policy, **kw):
    kw.setdefault("costs", StartCosts(cold=0.5, warm=0.1, hot=0.0))
    return WarmPool(policy, **kw)


# --------------------------------------------------------------------------- #
# estimators
# --------------------------------------------------------------------------- #


def test_ewma_rate_converges_and_decays():
    fc = ArrivalForecast(tau=10.0)
    t = 0.0
    while t < 100.0:  # steady 2/s stream
        fc.observe("f", t)
        t += 0.5
    assert fc.rate("f", 100.0) == pytest.approx(2.0, rel=0.15)
    # decays by e^{-dt/tau} without new arrivals
    assert fc.rate("f", 110.0) == pytest.approx(
        fc.rate("f", 100.0) * math.exp(-1.0), rel=1e-6)
    assert fc.rate("unseen", 50.0) == 0.0


def test_keep_until_is_a_firm_strict_crossing():
    fc = ArrivalForecast(tau=10.0)
    for k in range(20):
        fc.observe("f", k * 0.2)
    now = 4.0
    t_star = fc.keep_until("f", now, horizon=5.0, threshold=0.5)
    assert now < t_star < float("inf")
    # strictly below threshold AT the returned instant (the janitor fires an
    # event exactly there; equality would loop forever at one sim time)
    assert fc.expected_arrivals("f", t_star, 5.0) < 0.5
    assert fc.expected_arrivals("f", t_star - 0.01, 5.0) >= 0.5
    # already below threshold -> now
    assert fc.keep_until("f", now, 5.0, 1e9) == now


def test_seasonal_profile_tracks_the_cycle():
    sp = SeasonalProfile(period=40.0, nbins=8)
    rng = random.Random(0)
    # 10 periods: all arrivals in the first half of each period (the
    # observation stream is time-sorted, like a real trace)
    for p in range(10):
        for t in sorted(rng.random() * 20.0 for _ in range(40)):
            sp.observe(p * 40.0 + t)
        sp.observe(p * 40.0 + 39.9, weight=0.0)  # close the quiet bins too
    assert sp.factor(400.0 + 5.0) > 1.2  # ON half of the next period
    assert sp.factor(400.0 + 30.0) < 0.5  # OFF half


def test_successor_learning_and_affinity_seeding():
    fc = ArrivalForecast()
    reg = Registry()
    reg.register("divide", memory=1.0, tag="d")
    reg.register("impera", memory=1.0, tag="i")
    fc.seed_affinity(parse(AFFINE_SCRIPT), reg)
    seeded = fc.dag.successors("divide")
    assert [s.child for s in seeded] == ["impera"]
    assert seeded[0].count == pytest.approx(1.0)  # weak prior
    for _ in range(10):
        fc.observe_edge("divide", "impera", 2, 0.4)
    learned = fc.dag.successors("divide")[0]
    assert learned.count == pytest.approx(2.0, abs=0.2)  # data beats prior
    assert learned.lag == pytest.approx(0.4, abs=0.05)
    # successor demand scales with in-flight parents
    d = fc.successor_demand({"divide": 3}, horizon=5.0)
    assert d["impera"] == pytest.approx(3 * learned.count)


# --------------------------------------------------------------------------- #
# planner: Listing-1 validity, budget feasibility, migration, retirement
# --------------------------------------------------------------------------- #


def _affine_world():
    """2 workers; a divide runs on w1, so tag `d` is resident there."""
    reg = Registry()
    reg.register("divide", memory=100.0, tag="d")
    reg.register("impera", memory=100.0, tag="i")
    state = ClusterState()
    state.add_worker("w1", max_memory=1000.0)
    state.add_worker("w2", max_memory=1000.0)
    state.allocate("divide", "w1", reg)
    return reg, state


def _assert_actions_valid(actions, script, reg, conf):
    """The acceptance criterion: planner placements only ever target workers
    where ``core.scheduler.valid`` holds for the function's aAPP policy."""
    for a in actions:
        if isinstance(a, Prewarm):
            target = a.worker
        elif isinstance(a, Migrate):
            target = a.dst
        else:
            continue
        blocks = candidate_blocks(reg[a.function].tag, script)
        assert any(valid(a.function, target, conf, reg, b) for b in blocks), \
            f"planner placed {a.function} on invalid worker {target}"


def test_planner_prewarms_only_on_valid_workers_preferring_affinity():
    reg, state = _affine_world()
    script = parse(AFFINE_SCRIPT)
    fc = ArrivalForecast(tau=10.0)
    for k in range(30):  # hot impera demand
        fc.observe("impera", k * 0.1)
    pool = _pool(make_policy("predictive", ttl=3.0), budget_mb=500.0)
    planner = ForecastPlanner(fc, script, reg, PlanConfig())
    conf = state.conf()
    actions = planner.plan(conf, pool, 3.0)
    pres = [a for a in actions if isinstance(a, Prewarm)]
    assert pres, "expected prewarm actions for hot demand"
    _assert_actions_valid(actions, script, reg, conf)
    # the affinity block (rank 0) is valid only on w1 — preferred over the
    # default-block workers
    assert pres[0].worker == "w1"


def test_planner_honours_explicit_block_worker_lists():
    # Listing 1 lines 7-9: a block's explicit worker list bounds the
    # candidates — the planner must never park where the live scheduler
    # could not place, even if valid() would pass there
    reg, state = _affine_world()
    script = parse("""
d:
  workers: *
  strategy: random
i:
  workers: [w2]
  strategy: random
  followup: fail
""")
    fc = ArrivalForecast(tau=10.0)
    for k in range(30):
        fc.observe("impera", k * 0.1)
    pool = _pool(make_policy("predictive", ttl=3.0), budget_mb=500.0)
    planner = ForecastPlanner(fc, script, reg, PlanConfig())
    conf = state.conf()
    assert planner.valid_rank("impera", "w1", conf) == -1
    assert planner.valid_rank("impera", "w2", conf) == 0
    actions = planner.plan(conf, pool, 3.0)
    pres = [a for a in actions if isinstance(a, Prewarm)
            and a.function == "impera"]
    assert pres and all(a.worker == "w2" for a in pres)


def test_planner_respects_pool_budget():
    reg, state = _affine_world()
    script = parse(AFFINE_SCRIPT)
    fc = ArrivalForecast(tau=10.0)
    for k in range(30):
        fc.observe("impera", k * 0.1)
    # w1 budget already consumed by an idle divide (100/150); only one more
    # 100 MB container fits on w1, the rest must go to w2 (250 free)
    pool = _pool(make_policy("predictive", ttl=3.0),
                 budget_mb={"w1": 150.0, "w2": 250.0})
    c, _, _ = pool.acquire("divide", "w1", 0.0, memory=100.0, tag="d")
    pool.release(c.cid, 0.0)
    planner = ForecastPlanner(fc, script, reg, PlanConfig())
    actions = planner.plan(state.conf(), pool, 3.0)
    per_worker = {"w1": 50.0, "w2": 250.0}  # free budget before the plan
    for a in actions:
        if isinstance(a, Prewarm):
            per_worker[a.worker] -= a.memory
        elif isinstance(a, Retire):
            per_worker[a.worker] += reg[a.function].memory
    assert all(v >= 0 for v in per_worker.values()), \
        f"plan exceeds pool budget: {per_worker}"


def test_planner_migrates_stranded_container_to_affinity_worker():
    reg, state = _affine_world()
    script = parse(AFFINE_SCRIPT)
    fc = ArrivalForecast(tau=10.0)
    for k in range(30):
        fc.observe("impera", k * 0.1)
    pool = _pool(make_policy("predictive", ttl=3.0), budget_mb=500.0)
    # an idle impera stranded on w2 — the affinity block only holds on w1
    c, _, _ = pool.acquire("impera", "w2", 0.0, memory=100.0, tag="i")
    pool.release(c.cid, 0.0)
    conf = state.conf()
    actions = planner = ForecastPlanner(fc, script, reg, PlanConfig()).plan(
        conf, pool, 3.0)
    migs = [a for a in actions if isinstance(a, Migrate)]
    assert migs and migs[0].src == "w2" and migs[0].dst == "w1"
    _assert_actions_valid(actions, script, reg, conf)


def test_planner_retires_on_collapsed_demand():
    reg, state = _affine_world()
    script = parse(AFFINE_SCRIPT)
    fc = ArrivalForecast(tau=10.0)
    fc.observe("impera", 0.0)  # long-decayed single arrival
    pool = _pool(make_policy("predictive", ttl=3.0), budget_mb=500.0)
    c, _, _ = pool.acquire("impera", "w2", 0.0, memory=100.0, tag="i")
    pool.release(c.cid, 0.0)
    actions = ForecastPlanner(fc, script, reg, PlanConfig()).plan(
        state.conf(), pool, 500.0)
    assert any(isinstance(a, Retire) and a.function == "impera"
               for a in actions)
    # ...but never while the tag has pending in-flight demand
    pool.pending_add(["i"])
    actions = ForecastPlanner(fc, script, reg, PlanConfig()).plan(
        state.conf(), pool, 500.0)
    assert not any(isinstance(a, Retire) for a in actions)


# --------------------------------------------------------------------------- #
# predictive keep-alive policy
# --------------------------------------------------------------------------- #


def test_predictive_policy_retains_predicted_functions_past_ttl():
    fc = ArrivalForecast(tau=10.0)
    for k in range(40):
        fc.observe("f", k * 0.25)  # 4/s
    policy = PredictiveKeepAlive(ttl=3.0, horizon=6.0).bind(fc)
    pool = _pool(policy)
    c, _, _ = pool.acquire("f", "w", 9.0, memory=1.0, tag="x")
    pool.release(c.cid, 10.0)
    assert pool.sweep(14.0) == []  # past ttl but demand predicted: retained
    nxt = pool.next_event(14.0)
    assert nxt is not None and 14.0 < nxt < float("inf")  # firm, not polling
    assert len(pool.sweep(nxt)) == 1  # prediction decayed: ttl applies


def test_predictive_policy_unbound_matches_affinity():
    pred = PredictiveKeepAlive(ttl=5.0)
    aff = AffinityAwareKeepAlive(ttl=5.0)
    pool_p, pool_a = _pool(pred), _pool(aff)
    for pool in (pool_p, pool_a):
        c, _, _ = pool.acquire("f", "w", 0.0, memory=1.0, tag="x")
        pool.release(c.cid, 1.0)
    assert pool_p.next_event(2.0) == pool_a.next_event(2.0) == 6.0
    assert len(pool_p.sweep(6.0)) == len(pool_a.sweep(6.0)) == 1


# --------------------------------------------------------------------------- #
# pool entry points: prewarm / migrate
# --------------------------------------------------------------------------- #


def test_prewarm_first_use_is_a_warm_hit():
    pool = _pool(make_policy("fixed_ttl", ttl=100.0), hot_window=2.0)
    c = pool.prewarm("f", "w", 0.0, memory=1.0, tag="x")
    assert c is not None and pool.metrics.prewarm_starts == 1
    assert pool.warmth("f", "w", 0.5) == 1  # advertised warm, never hot
    got, kind, cost = pool.acquire("f", "w", 0.5, memory=1.0)
    assert got.cid == c.cid and kind == "warm" and cost == 0.1
    assert pool.metrics.prewarm_hits == 1 and pool.metrics.cold_starts == 0
    # second use of the same container is a normal hot hit again
    pool.release(got.cid, 1.0)
    assert pool.warmth("f", "w", 1.5) == 2


def test_prewarm_refused_over_budget_never_evicts():
    pool = _pool(make_policy("fixed_ttl", ttl=100.0), budget_mb=2.0)
    c, _, _ = pool.acquire("f", "w", 0.0, memory=2.0)
    pool.release(c.cid, 1.0)
    assert pool.prewarm("g", "w", 2.0, memory=1.0) is None
    assert pool.idle_count("w") == 1  # the earned warm set is untouched
    # the refused boot is still visible as a started-and-wasted prewarm
    assert pool.metrics.prewarm_starts == 1
    assert pool.metrics.prewarm_wasted == 1


def test_unused_prewarm_counts_as_wasted():
    pool = _pool(make_policy("fixed_ttl", ttl=5.0))
    pool.prewarm("f", "w", 0.0, memory=1.0)
    assert len(pool.sweep(5.0)) == 1
    assert pool.metrics.prewarm_wasted == 1
    assert pool.metrics.prewarm_waste_ratio == 1.0


def test_migrate_moves_idle_container_between_workers():
    pool = _pool(make_policy("fixed_ttl", ttl=100.0))
    c, _, _ = pool.acquire("f", "w1", 0.0, memory=1.0, tag="x")
    pool.release(c.cid, 1.0)
    moved = pool.migrate("f", "w1", "w2", 2.0)
    assert moved is not None and moved.cid == c.cid and moved.worker == "w2"
    assert pool.metrics.migrations == 1
    assert pool.residency_counts() == {("w2", "f"): 1}
    assert pool.acquire("f", "w2", 3.0, memory=1.0)[1] != "cold"


def test_migrate_in_refused_when_destination_filled_up():
    pool = _pool(make_policy("fixed_ttl", ttl=100.0), budget_mb=1.0)
    c, _, _ = pool.acquire("f", "w1", 0.0, memory=1.0)
    pool.release(c.cid, 1.0)
    mid = pool.migrate_out("f", "w1", 2.0)
    pool.acquire("g", "w2", 2.0, memory=1.0)  # dst budget fills mid-transfer
    assert pool.migrate_in(mid, "w2", 2.5) is False
    assert mid.state.value == "dead" and pool.metrics.migrations == 0


# --------------------------------------------------------------------------- #
# end-to-end: predictive simulator run
# --------------------------------------------------------------------------- #

BENCH_SCRIPT = """
api:
  workers: *
  strategy: random
img:
  workers: *
  strategy: random
etl:
  workers: *
  strategy: random
d:
  workers: *
  strategy: random
i:
  workers: *
  strategy: random
  affinity: [d]
"""


class _CheckedPlanner(ForecastPlanner):
    """Re-asserts Listing-1 validity for every placement at every epoch."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.actions = []

    def plan(self, conf, pool, now):
        actions = super().plan(conf, pool, now)
        _assert_actions_valid(actions, self.script, self.registry, conf)
        self.actions.extend(actions)
        return actions


def _run_predictive(scenario, seed=0, duration=90.0):
    policy = make_policy("predictive", ttl=3.0)
    pool = _pool(policy, budget_mb=512.0, hot_window=1.0)
    sim = ClusterSim(paper_testbed(), SimParams(), seed=seed, pool=pool,
                     plan_interval=1.0)
    register_functions(sim.registry)
    script = parse(BENCH_SCRIPT)
    fc = ArrivalForecast(tau=20.0)
    fc.seed_affinity(script, sim.registry)
    policy.bind(fc)
    planner = _CheckedPlanner(fc, script, sim.registry, PlanConfig())
    sim.planner = planner
    rng = random.Random(seed + 1)

    def scheduler(f):
        return try_schedule(f, sim.state.conf(), script, sim.registry,
                            rng=rng,
                            warmth=lambda fn, w: pool.warmth(fn, w, sim.now))

    wl = TraceWorkload(sim, scheduler, COMPUTE_S, script=script, forecast=fc)
    wl.load(build_trace(scenario, duration=duration, rate=2.0, seed=seed))
    sim.run()
    return pool, wl, planner


def test_sim_predictive_terminates_and_validly_prewarms():
    pool, wl, planner = _run_predictive("chained")
    m = pool.metrics
    ok = [r for r in wl.records if not r.failed]
    assert m.total_starts == len(ok) and len(ok) > 0
    # the chained DAG drives successor prewarms; every one was Listing-1
    # valid at plan time (asserted inside _CheckedPlanner) and was charged
    assert m.prewarm_starts > 0
    assert m.prewarm_seconds > 0
    assert m.prewarm_hits + m.prewarm_wasted <= m.prewarm_starts


def test_sim_predictive_beats_affinity_cold_rate_on_poisson():
    pool, _, _ = _run_predictive("poisson")

    aff_pool = _pool(make_policy("affinity", ttl=3.0), budget_mb=512.0,
                     hot_window=1.0)
    sim = ClusterSim(paper_testbed(), SimParams(), seed=0, pool=aff_pool)
    register_functions(sim.registry)
    script = parse(BENCH_SCRIPT)
    rng = random.Random(1)
    wl = TraceWorkload(
        sim,
        lambda f: try_schedule(f, sim.state.conf(), script, sim.registry,
                               rng=rng,
                               warmth=lambda fn, w: aff_pool.warmth(fn, w, sim.now)),
        COMPUTE_S, script=script)
    wl.load(build_trace("poisson", duration=90.0, rate=2.0, seed=0))
    sim.run()
    assert pool.metrics.cold_start_rate < aff_pool.metrics.cold_start_rate


# --------------------------------------------------------------------------- #
# engine: forecast feed + stats
# --------------------------------------------------------------------------- #


def test_engine_feeds_estimator_and_exposes_forecast_stats():
    t = [0.0]

    def clock():
        return t[0]

    def runner(req, cell):
        t[0] += 0.01
        return "ok"

    fc = ArrivalForecast(tau=10.0)
    eng = Engine(two_pod_cells(), runner=runner, clock=clock,
                 heartbeat_timeout=1e9, forecast=fc)
    eng.deploy("m1", ["pod0-cell0", "pod0-cell1"], weights_gb=8)
    for _ in range(5):
        eng.submit(Request(model="m1", kind="decode"))
        t[0] += 0.2
    stats = eng.forecast_stats()
    assert "decode-m1" in stats
    assert stats["decode-m1"]["rate_per_s"] > 0
    assert stats["decode-m1"]["service_s"] == pytest.approx(0.01, abs=0.005)
    assert Engine(two_pod_cells(), runner=runner,
                  clock=clock).forecast_stats() == {}
