"""The unified `repro.platform.Platform` facade.

Covers the full surface (`register/invoke/complete/advance/reload_script/
explain`), the explain-trace acceptance contract (affinity and
anti-affinity rejections asserted per worker), decision agreement with the
scalar reference, pool/planner integration, and end-to-end seeded
reproducibility.
"""
import random

import pytest

from repro.core import SchedulingFailure, try_schedule
from repro.core.decision import (
    REASON_MEMORY,
    REASON_WARMTH_TIER,
)
from repro.platform import Platform
from repro.pool import StartCosts, WarmPool, make_policy

SCRIPT = """
d:
  workers: *
  strategy: best_first
  affinity: [!h]
i:
  - workers: *
    strategy: best_first
    affinity: [d]
  - followup: fail
h:
  workers: [w2]
"""


def _platform(**kw):
    kw.setdefault("cluster", {"w0": 8.0, "w1": 8.0, "w2": 8.0})
    plat = Platform.from_yaml(SCRIPT, **kw)
    plat.register("divide", memory=1.0, tag="d")
    plat.register("impera", memory=1.0, tag="i")
    plat.register("heavy", memory=4.0, tag="h")
    return plat


# --------------------------------------------------------------------------- #
# lifecycle surface
# --------------------------------------------------------------------------- #


def test_invoke_complete_roundtrip():
    plat = _platform()
    h = plat.invoke("heavy")
    assert h.ok and h.worker == "w2" and h.activation_id
    d = plat.invoke("divide")
    assert d.worker == "w0"  # anti-affine with h -> first heavy-free worker
    i = plat.invoke("impera")
    assert i.worker == d.worker  # affine with d
    assert plat.state.tag_counts(d.worker) == {"d": 1, "i": 1}
    plat.complete(d)
    plat.complete(i.activation_id)  # raw activation-id shape works too
    assert plat.state.tag_counts(d.worker) == {}
    with pytest.raises(ValueError):
        plat.complete(plat.decide("divide"))  # never applied -> no id


def test_unschedulable_returns_falsy_decision():
    plat = _platform()
    plat.invoke("heavy")
    for _ in range(3):
        plat.invoke("divide")
    # impera is affine to d; fill every d-worker's memory with heavies? no —
    # simplest: an unknown-tag impera on a cluster without d is fine, so
    # instead drop all workers hosting d
    plat2 = _platform()
    d = plat2.invoke("impera")  # no divide resident anywhere, followup: fail
    assert not d.ok and d.worker is None and not d
    assert d.activation_id is None


def test_decisions_match_scalar_reference():
    plat = _platform(seed=11)
    ref_rng = random.Random(99)
    got_rng = random.Random(99)
    fns = ["heavy", "divide", "impera", "divide", "impera", "impera"]
    for f in fns:
        want = try_schedule(f, plat.state.conf(), plat.script, plat.registry,
                            rng=ref_rng)
        got = plat.invoke(f, rng=got_rng)
        assert got.worker == want, (f, got.worker, want)


def test_fail_worker_and_add_worker():
    plat = _platform()
    d = plat.invoke("divide")
    lost = plat.fail_worker(d.worker)
    assert [a.activation_id for a in lost] == [d.activation_id]
    assert d.worker not in plat.workers()
    plat.add_worker("w9", max_memory=8.0)
    assert "w9" in plat.workers()


def test_seeded_runs_reproduce():
    """Same seed -> identical `strategy: any` draws, end to end."""
    script = "t:\n  workers: *\n  strategy: random\n"
    def run(seed):
        plat = Platform.from_yaml(script,
                                  cluster={f"w{i}": 8.0 for i in range(6)},
                                  seed=seed)
        plat.register("fn", memory=1.0, tag="t")
        return [plat.invoke("fn").worker for _ in range(10)]
    assert run(7) == run(7)
    assert run(7) != run(8)  # and the seed actually matters


# --------------------------------------------------------------------------- #
# explain traces (acceptance: affinity + anti-affinity rejections)
# --------------------------------------------------------------------------- #


def test_explain_affinity_rejection():
    """impera is affine to d: every worker without a resident divide is
    rejected with the `affinity:d` reason; once a divide lands, the trace
    shows exactly its worker as valid/selected."""
    plat = _platform()
    probe = plat.explain("impera")
    assert not probe.ok and probe.trace is not None
    bt = probe.trace[0]
    assert all(v.reason == "affinity:d" for v in bt.workers)
    assert probe.rejection_reasons("w0") == ("affinity:d",)

    d = plat.invoke("divide")
    probe = plat.explain("impera")
    assert probe.ok and probe.worker == d.worker
    verdicts = {v.worker: v for v in probe.trace[-1].workers}
    assert verdicts[d.worker].ok and verdicts[d.worker].reason is None
    for w in plat.workers():
        if w != d.worker:
            assert verdicts[w].reason == "affinity:d"
    assert probe.trace[-1].selected == d.worker
    assert probe.block_index == 0 and probe.strategy == "best_first"


def test_explain_anti_affinity_rejection():
    """d is anti-affine to h: the cell hosting the heavy is rejected with
    the `anti-affinity:h` reason; the others stay valid."""
    plat = _platform()
    h = plat.invoke("heavy")
    probe = plat.explain("divide")
    assert probe.ok
    verdicts = {v.worker: v for v in probe.trace[0].workers}
    assert verdicts[h.worker].reason == "anti-affinity:h"
    assert not verdicts[h.worker].ok
    assert verdicts[probe.worker].ok
    assert "anti-affinity:h" in probe.format()


def test_explain_memory_and_warmth_reasons():
    plat = _platform()
    # fill w0 with heavies until divide no longer fits anywhere but w1
    plat.state.allocate("heavy", "w0", plat.registry)
    plat.state.allocate("heavy", "w0", plat.registry)  # w0 8.0/8.0 used
    probe = plat.explain("divide")
    verdicts = {v.worker: v for v in probe.trace[0].workers}
    assert verdicts["w0"].reason == REASON_MEMORY
    assert probe.worker == "w1"


def test_explain_warmth_tier_drop():
    pool = WarmPool(make_policy("fixed_ttl", ttl=1e9),
                    costs=StartCosts(), budget_mb=64.0, hot_window=1e9)
    plat = _platform(pool=pool)
    d = plat.invoke("divide")  # acquires a cold container on w0
    plat.complete(d)  # parks it -> w0 is warm for "divide"
    probe = plat.explain("divide")
    assert probe.worker == "w0"
    verdicts = {v.worker: v for v in probe.trace[0].workers}
    # w1 was Listing-1-valid but lost to the warmth tier narrowing
    assert verdicts["w1"].reason == REASON_WARMTH_TIER
    # explain consumed nothing from the platform rng and allocated nothing
    assert plat.state.tag_counts("w0") == {}


def test_explain_agrees_with_session_decision():
    for seed in range(20):
        plat = _platform(seed=seed)
        if seed % 3 == 0:
            plat.invoke("heavy")
        if seed % 2 == 0:
            plat.invoke("divide")
        for f in ("divide", "impera", "heavy"):
            assert plat.explain(f).worker == plat.decide(f).worker, (seed, f)


# --------------------------------------------------------------------------- #
# script lifecycle / time / pool
# --------------------------------------------------------------------------- #


def test_reload_script_hot_swaps_policies():
    plat = _platform()
    plat.invoke("heavy")
    assert plat.invoke("divide").worker == "w0"
    # flip d to *require* co-location with h instead of refusing it
    plat.reload_script(SCRIPT.replace("affinity: [!h]", "affinity: [h]"))
    assert plat.invoke("divide").worker == "w2"
    # the trace explains under the new script too
    probe = plat.explain("divide")
    assert {v.worker: v.reason for v in probe.trace[0].workers}["w0"] == "affinity:h"


def test_invoke_charges_container_starts_and_advance_sweeps():
    pool = WarmPool(make_policy("fixed_ttl", ttl=2.0),
                    costs=StartCosts(cold=0.5, warm=0.1, hot=0.0),
                    budget_mb=64.0, hot_window=0.5)
    plat = _platform(pool=pool)
    d = plat.invoke("divide")
    assert d.start_kind == "cold" and d.start_cost == 0.5
    plat.complete(d)
    d2 = plat.invoke("divide")  # inside the hot window
    assert d2.start_kind == "hot" and d2.start_cost == 0.0
    plat.complete(d2)
    plat.advance(10.0)  # past the TTL: the janitor retires the idle container
    assert plat.clock() == 10.0
    d3 = plat.invoke("divide")
    assert d3.start_kind == "cold"
    assert plat.stats()["pool"]["evictions_ttl"] >= 1


def test_advance_refuses_on_external_clock():
    now = [0.0]
    plat = _platform(clock=lambda: now[0])
    with pytest.raises(ValueError):
        plat.advance(1.0)
    now[0] = 5.0
    assert plat.advance(0.0) == 5.0  # sweep-at-current-time is fine


def test_advance_runs_planner_epochs():
    from repro.forecast import ArrivalForecast, ForecastPlanner, PlanConfig

    pool = WarmPool(make_policy("fixed_ttl", ttl=1e9),
                    costs=StartCosts(), budget_mb=64.0)
    fc = ArrivalForecast(tau=5.0)
    plat = _platform(pool=pool, forecast=fc)
    plat.planner = ForecastPlanner(fc, plat.compiled, plat.registry,
                                   PlanConfig())
    for _ in range(25):  # steady divide arrivals teach the estimator
        d = plat.invoke("divide")
        plat.advance(0.25)
        plat.complete(d, service_time=0.2)
    plat.advance(0.25)
    assert plat.stats()["pool"]["prewarm_starts"] >= 1


def test_compile_diagnostics_surface_on_platform():
    plat = Platform.from_yaml("t:\n  workers: *\n  affinity: [ghost]\n",
                              cluster={"w0": 4.0})
    assert any("ghost" in d.message for d in plat.diagnostics)
