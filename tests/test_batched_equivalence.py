"""`schedule_wave` is bit-identical to a scalar `schedule` loop — the promise
`repro.core.batched`'s docstring makes, including the warmth-rank column.

Property-style but hypothesis-free: scripts / clusters / waves / warmth maps
are generated from seeded ``random.Random`` instances so the sweep runs in the
minimal environment and is perfectly reproducible.
"""
import random

from repro.core import (
    AAppScript,
    Affinity,
    Block,
    ClusterState,
    CompiledPolicies,
    Invalidate,
    Registry,
    SchedulerSession,
    TagPolicy,
    schedule_wave,
    try_schedule,
)

TAGS = ["a", "b", "c", "d"]
WORKERS = [f"w{i}" for i in range(8)]


def random_script(rng: random.Random) -> AAppScript:
    policies = []
    for tag in TAGS:
        blocks = []
        for _ in range(rng.randint(1, 3)):
            if rng.random() < 0.5:
                workers = ("*",)
            else:
                k = rng.randint(1, 4)
                workers = tuple(rng.sample(WORKERS + ["ghost"], k))
            aff, anti = [], []
            for t in TAGS:
                r = rng.randint(0, 5)
                if r == 0:
                    aff.append(t)
                elif r == 1:
                    anti.append(t)
            blocks.append(Block(
                workers=workers,
                # the full registered strategy set: the equivalence sweeps
                # cover the new least_loaded / warmest rules too
                strategy=rng.choice(["best_first", "any",
                                     "least_loaded", "warmest", "min_cost"]),
                invalidate=Invalidate(
                    capacity_used=rng.choice([None, 40.0, 80.0]),
                    max_concurrent_invocations=rng.choice([None, 1, 4]),
                ),
                affinity=Affinity(affine=tuple(aff), anti_affine=tuple(anti)),
            ))
        policies.append(TagPolicy(tag=tag, blocks=tuple(blocks),
                                  followup=rng.choice(["default", "fail"])))
    return AAppScript(policies=tuple(policies))


def random_cluster(rng: random.Random):
    n = rng.randint(1, 8)
    state = ClusterState()
    reg = Registry()
    for i in range(n):
        state.add_worker(f"w{i}", max_memory=rng.choice([20.0, 50.0, 100.0]))
    for t in TAGS:
        reg.register(f"fn_{t}", memory=rng.choice([1.0, 10.0, 30.0]), tag=t)
    for _ in range(rng.randint(0, 10)):
        w = f"w{rng.randrange(n)}"
        f = f"fn_{rng.choice(TAGS)}"
        view = state.conf()[w]
        if view.memory_used + reg[f].memory <= view.max_memory:
            state.allocate(f, w, reg)
    return state, reg


def clone_state(state: ClusterState, reg: Registry) -> ClusterState:
    out = ClusterState()
    for w, view in state.conf().items():
        out.add_worker(w, max_memory=view.max_memory)
    for act in state.active_activations():
        out.allocate(act.function, act.worker, reg)
    return out


def random_warmth(rng: random.Random):
    table = {(f"fn_{t}", w): rng.randint(0, 2) for t in TAGS for w in WORKERS}
    return lambda f, w: table.get((f, w), 0)


def _check_seed(seed: int, with_warmth: bool) -> None:
    rng = random.Random(seed)
    script = random_script(rng)
    state, reg = random_cluster(rng)
    fs = [f"fn_{rng.choice(TAGS)}" for _ in range(rng.randint(1, 12))]
    warmth = random_warmth(rng) if with_warmth else None

    ref_state = clone_state(state, reg)
    ref_rng = random.Random(seed * 7 + 1)
    expected = []
    for f in fs:
        w = try_schedule(f, ref_state.conf(), script, reg, rng=ref_rng,
                         warmth=warmth)
        expected.append(w)
        if w is not None:
            ref_state.allocate(f, w, reg)

    pol = CompiledPolicies(script, reg)
    res = schedule_wave(fs, state.conf(), pol, reg,
                        rng=random.Random(seed * 7 + 1), backend="ref",
                        warmth=warmth)
    assert res.assignments == expected, (
        f"seed={seed} warmth={with_warmth}: {res.assignments} != {expected}")


def _check_seed_session(seed: int, with_warmth: bool) -> None:
    """Same sweep through the *incremental* data plane: a SchedulerSession
    over a live ClusterState must match the scalar loop decision for
    decision, with allocations flowing back as tensor deltas."""
    rng = random.Random(seed)
    script = random_script(rng)
    state, reg = random_cluster(rng)
    fs = [f"fn_{rng.choice(TAGS)}" for _ in range(rng.randint(1, 12))]
    warmth = random_warmth(rng) if with_warmth else None

    ref_state = clone_state(state, reg)
    ref_rng = random.Random(seed * 7 + 1)
    expected = []
    for f in fs:
        w = try_schedule(f, ref_state.conf(), script, reg, rng=ref_rng,
                         warmth=warmth)
        expected.append(w)
        if w is not None:
            ref_state.allocate(f, w, reg)

    session = SchedulerSession(state, reg, script)
    # wave mode against the live state (deltas applied between decisions)
    res = session.schedule_wave(fs, rng=random.Random(seed * 7 + 1),
                                warmth=warmth, apply_to=state)
    assert res.assignments == expected, (
        f"seed={seed} warmth={with_warmth}: {res.assignments} != {expected}")


def test_wave_equals_scalar_loop():
    for seed in range(60):
        _check_seed(seed, with_warmth=False)


def test_wave_equals_scalar_loop_with_warmth_rank():
    for seed in range(60):
        _check_seed(seed, with_warmth=True)


def test_session_wave_equals_scalar_loop():
    for seed in range(60):
        _check_seed_session(seed, with_warmth=False)


def test_session_wave_equals_scalar_loop_with_warmth_rank():
    for seed in range(60):
        _check_seed_session(seed, with_warmth=True)


def test_session_scheduler_fn_equals_scalar_under_churn():
    """scheduler_fn style: one decision at a time, the caller allocates and
    completes between decisions — the session must track every delta."""
    for seed in range(40):
        rng = random.Random(seed + 500)
        script = random_script(rng)
        state, reg = random_cluster(rng)
        session = SchedulerSession(state, reg, script)
        ref_rng, got_rng = random.Random(seed), random.Random(seed)
        live = []
        for step in range(15):
            f = f"fn_{rng.choice(TAGS)}"
            want = try_schedule(f, state.conf(), script, reg, rng=ref_rng)
            got = session.try_schedule(f, rng=got_rng)
            assert got == want, (seed, step, got, want)
            if got is not None:
                live.append(state.allocate(f, got, reg).activation_id)
            if live and rng.random() < 0.4:
                state.complete(live.pop(rng.randrange(len(live))))


def test_warmth_narrows_to_hottest_tier():
    """Deterministic: both paths pick the warm worker over the conf-first one."""
    state = ClusterState()
    reg = Registry()
    for w in ("w0", "w1", "w2"):
        state.add_worker(w, max_memory=100.0)
    reg.register("fn_a", memory=1.0, tag="a")
    script = AAppScript(policies=(
        TagPolicy(tag="a", blocks=(Block(workers=("*",)),)),))
    warmth = lambda f, w: {"w1": 2}.get(w, 0)

    chosen = try_schedule("fn_a", state.conf(), script, reg, warmth=warmth)
    assert chosen == "w1"  # best_first alone would pick w0

    res = schedule_wave(["fn_a"], state.conf(), CompiledPolicies(script, reg),
                        reg, backend="ref", warmth=warmth)
    assert res.assignments == ["w1"]

    # without warmth both fall back to conf order
    assert try_schedule("fn_a", state.conf(), script, reg) == "w0"
    res = schedule_wave(["fn_a"], state.conf(), CompiledPolicies(script, reg),
                        reg, backend="ref")
    assert res.assignments == ["w0"]
