"""Latency attribution, the SLO burn-rate engine, and what-if replay.

Three contracts from the observability PR:

* **exact-sum attribution** — for every invocation record, the canonical
  component sum reproduces the end-to-end latency *bit-exactly*
  (``total(components) == latency + parent_wait``), property-tested over
  seeds on the chained and multiregion scenarios, with the per-phase
  values pinned to the simulator charges they name (sched = front-door
  overhead, boot = the pool's cold/warm/hot cost, route = the zone terms);
* **SLO engine** — sliding-window burn rates, multi-window alerting, and
  error-budget accounting on virtual time, surfaced through
  ``Obs.snapshot()``/``render()`` and ``Platform.stats()``;
* **what-if replay** — a same-policy replay reproduces decisions, rng
  draws, and per-component latencies bit-identically; an alternate-policy
  replay yields per-activation diffs whose deltas decompose into shifted
  components; the attribution-annotated timeline validates (and a span
  stripped of its components fails).
"""
import dataclasses

import pytest

try:  # seed sweeps use hypothesis when present
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.cluster.simulator import SimParams
from repro.obs import Obs, SloEngine, SloObjective
from repro.obs.attribution import (
    COMPONENTS,
    build,
    check,
    summarize,
    total,
)
from repro.platform import Platform
from repro.workload import (
    ReplayConfig,
    diff_runs,
    replay_identical,
    run_config,
    validate_replay_timeline,
    whatif,
)
from repro.workload.replay import chrome_trace

# --------------------------------------------------------------------------- #
# exact-sum invariant
# --------------------------------------------------------------------------- #


def _run(scenario, seed, duration=30.0):
    return run_config(ReplayConfig(scenario=scenario, seed=seed,
                                   duration=duration))


def _assert_exact(run):
    assert run.records, "scenario produced no records"
    for r in run.records:
        check(r)
        if not r.failed:
            assert total(r.components) == r.latency + \
                r.components["parent_wait"]


def test_exact_sum_chained_and_multiregion():
    for scenario in ("chained", "multiregion"):
        _assert_exact(_run(scenario, seed=0))


if HAS_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_exact_sum_chained_property(seed):
        _assert_exact(_run("chained", seed, duration=20.0))

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_exact_sum_multiregion_property(seed):
        _assert_exact(_run("multiregion", seed, duration=20.0))
else:
    def test_exact_sum_seed_sweep_fallback():
        for seed in range(4):
            _assert_exact(_run("chained", seed, duration=20.0))
            _assert_exact(_run("multiregion", seed, duration=20.0))


def test_components_name_the_simulator_charges():
    run = _run("poisson", seed=0)
    costs = {"cold": 0.5, "warm": 0.1, "hot": 0.0}
    for r in run.records:
        if r.failed:
            continue
        c = r.components
        # sched is exactly the platform front-door overhead
        assert c["sched"] == SimParams().invoke_overhead
        # boot is the warm pool's charged start cost (the exact-sum
        # closure may nudge it by a half-ulp-scale tie-break residue)
        assert abs(c["boot"] - costs[r.start_kind]) < 1e-12
        # no policy charges invocation-path migrations yet
        assert c["migrate"] == 0.0
        # roots never wait on a parent
        assert c["parent_wait"] == 0.0
        assert c["service"] >= -1e-9  # residual closure is sub-ulp only


def test_route_component_zone_terms():
    # paper testbed: control plane in eu, us workers pay us_overhead;
    # zone-agnostic arrivals never pay the cross-zone front-door hop
    run = _run("poisson", seed=3)
    for r in run.records:
        if r.failed:
            continue
        expected = SimParams().us_overhead if "us" in r.worker else 0.0
        assert r.components["route"] == expected
    # multiregion: zone-stamped arrivals placed outside their origin zone
    # add the cross-zone hop on top of the control-plane distance
    mrun = _run("multiregion", seed=0)
    zone_cost = {"eu": 0.0, "us": SimParams().us_overhead,
                 "ap": SimParams().us_overhead}
    cross = 0.35  # the replay stack's multiregion cross_zone_route
    hops = 0
    for r in mrun.records:
        if r.failed:
            continue
        wz = r.worker[len("worker"):][:2]
        hop = 0.0
        if r.origin_zone is not None and r.origin_zone != wz:
            hop = cross
            hops += 1
        assert r.components["route"] == zone_cost[wz] + hop
    assert hops, "no cross-zone placements in the skewed multiregion trace"


def test_chained_parent_wait_extends_to_root():
    run = _run("chained", seed=1)
    children = [r for r in run.records
                if not r.failed and r.arrival_id and "/" in r.arrival_id]
    assert children, "chained scenario spawned no children"
    for r in children:
        assert r.components["parent_wait"] > 0.0
        assert r.components["parent_wait"] == r.t_submit - r.t_root


def test_build_closes_tie_locked_floats():
    # regression: this chained-run case once left the window's partial sum
    # exactly half an ulp off the target's grid, so every service candidate
    # was a round-to-even tie and the naive closure looped forever
    service = 7.518728815810424 - 0.9
    comps = build(sched=0.05, boot=0.5, migrate=0.0, route=0.35,
                  service=service, parent_wait=0.3500000000000003,
                  latency=7.518728815810424)
    assert total(comps) == 7.518728815810424 + comps["parent_wait"]
    # the tie-break perturbations stay far below any physical quantity
    assert abs(comps["boot"] - 0.5) < 1e-9
    assert abs(comps["service"] - service) < 1e-6
    assert comps["parent_wait"] == 0.3500000000000003  # never adjusted


def test_check_rejects_broken_components():
    run = _run("poisson", seed=0, duration=10.0)
    r = next(x for x in run.records if not x.failed)
    broken = dict(r.components)
    broken["boot"] += 0.1
    bad = dataclasses.replace(r, components=broken)
    with pytest.raises(AssertionError):
        check(bad)


def test_attributor_registry_histograms_and_summary():
    run = _run("multiregion", seed=0)
    snap = run.obs.snapshot()
    keys = [k for k in snap if k.startswith("attr.")]
    assert any(".api.boot_s.count" in k for k in keys)
    assert any(k.startswith("attr.eu.") for k in keys)  # zone-labelled
    # histogram counts add up to the successful record count per function
    n_api = sum(snap[k] for k in keys if ".api.service_s.count" in k)
    assert n_api == sum(1 for r in run.records
                        if r.function == "api" and not r.failed)
    by_fn = summarize(run.records, by="function")
    assert set(by_fn) <= {"api", "thumb", "etl", "divide", "impera"}
    for row in by_fn.values():
        assert row["e2e"] == pytest.approx(
            sum(row[c] for c in COMPONENTS))


# --------------------------------------------------------------------------- #
# SLO engine
# --------------------------------------------------------------------------- #


def test_slo_objective_validation():
    with pytest.raises(ValueError):
        SloObjective("f", threshold_s=1.0, compliance=1.0)
    with pytest.raises(ValueError):
        SloObjective("f", threshold_s=0.0)
    o = SloObjective("f", threshold_s=1.0, compliance=0.99)
    assert o.error_budget == pytest.approx(0.01)
    assert o.target_quantile == 0.99


def test_slo_burn_rates_and_alerting():
    eng = SloEngine({"api": SloObjective("api", threshold_s=1.0,
                                         compliance=0.9)},
                    fast_window=10.0, slow_window=100.0, alert_burn=1.0)
    # steady compliant traffic: no burn
    for i in range(100):
        eng.observe("api", float(i), 0.5)
    assert eng.burn_rates("api") == (0.0, 0.0)
    assert eng.alerts() == []
    assert eng.budget_remaining("api") == 1.0
    # a breach spike saturates the fast window but dilutes in the slow one
    for i in range(100, 110):
        eng.observe("api", float(i), 5.0)
    fast, slow = eng.burn_rates("api")
    assert fast > 1.0
    assert slow < fast
    # multi-window AND: the fast spike alone must not alert
    assert slow < 1.0 and not eng.alerting("api")
    # sustained burn trips both windows
    for i in range(110, 220):
        eng.observe("api", float(i), 5.0)
    assert eng.alerting("api")
    assert eng.alerts() == ["api"]
    assert eng.budget_remaining("api") < 1.0


def test_slo_window_slides_on_virtual_time():
    eng = SloEngine({"api": 1.0}, fast_window=10.0, slow_window=50.0)
    for i in range(10):
        eng.observe("api", float(i), 9.0)  # all breaches
    assert eng.burn_rates("api")[0] > 0.0
    # quiet period: the windows slide past the breaches
    eng.observe("api", 200.0, 0.1)
    assert eng.burn_rates("api") == (0.0, 0.0)


def test_slo_snapshot_render_and_platform_stats():
    slo = SloEngine({"divide": 0.5}, fast_window=5.0, slow_window=20.0)
    obs = Obs.enabled(slo=slo, timers=False)
    plat = Platform.from_yaml(
        "d:\n  workers: *\n  strategy: best_first\n",
        cluster={"w0": 8.0}, obs=obs)
    plat.register("divide", memory=1.0, tag="d")
    slo.observe("divide", 1.0, 0.2)
    slo.observe("divide", 2.0, 0.9)
    stats = plat.stats()
    assert stats["slo"]["divide"]["observed"] == 2
    assert stats["slo"]["divide"]["breaches"] == 1
    # alerting exports as 0/1 so the Prometheus render keeps the row
    assert isinstance(stats["slo"]["divide"]["alerting"], int)
    snap = obs.snapshot()
    assert snap["slo.divide.observed"] == 2
    assert "slo_divide_burn_fast" in obs.render()


def test_slo_unknown_function_is_ignored():
    eng = SloEngine({"api": 1.0})
    eng.observe("other", 1.0, 99.0)  # no objective: free no-op
    assert "other" not in eng and "api" in eng
    assert set(eng.snapshot()) == {"api"}


def test_slo_fed_by_workload_driver():
    run = run_config(ReplayConfig(scenario="poisson", duration=30.0,
                                  slo={"api": 0.6, "etl": 2.0}))
    slo = run.obs.slo.snapshot()
    n_api = sum(1 for r in run.records
                if r.function == "api" and not r.failed)
    assert n_api and slo["api"]["observed"] == n_api
    assert run.platform.stats()["slo"]["api"]["observed"] == n_api


# --------------------------------------------------------------------------- #
# what-if replay
# --------------------------------------------------------------------------- #


def test_same_policy_replay_bit_identical():
    base = _run("chained", seed=2)
    again = run_config(base.config, trace=base.trace)
    assert replay_identical(base, again) == []


def test_alternate_strategy_diff_decomposes_deltas():
    base = _run("chained", seed=0)
    d = whatif(base, strategy="least_loaded")
    assert d.entries, "counterfactual produced no comparable activations"
    _assert_exact(d.alt)  # the invariant holds under the alternate policy
    for e in d.entries:
        assert e["dominant"] in COMPONENTS
        # the latency delta is the component deltas minus the parent_wait
        # shift (which extends the window, not the measured latency)
        recomposed = sum(e["components_delta"][k] for k in COMPONENTS)
        assert recomposed - e["components_delta"]["parent_wait"] == \
            pytest.approx(e["delta"], abs=1e-9)
        assert e["note"]
    # the diff is sorted biggest-mover-first
    deltas = [abs(e["delta"]) for e in d.entries]
    assert deltas == sorted(deltas, reverse=True)


def test_whatif_keepalive_counterfactual():
    base = _run("bursty", seed=1)
    d = whatif(base, keepalive="affinity")
    # same trace, same front door: the sched charge can never shift
    assert all(e["components_delta"]["sched"] == 0.0 for e in d.entries)
    assert d.alt.config.keepalive == "affinity"
    _assert_exact(d.alt)


def test_replay_timeline_valid_and_negative():
    base = _run("chained", seed=0, duration=20.0)
    obj = chrome_trace(base)
    assert validate_replay_timeline(obj) == []
    # negative: strip one invoke span's components entirely
    for ev in obj["traceEvents"]:
        if ev.get("cat") == "invoke" and ev.get("ph") == "X":
            del ev["args"]["components"]
            break
    errs = validate_replay_timeline(obj)
    assert errs and "missing components" in errs[0]
    # a partially-stripped taxonomy is named, not just flagged
    obj2 = chrome_trace(base)
    for ev in obj2["traceEvents"]:
        if ev.get("cat") == "invoke" and ev.get("ph") == "X":
            del ev["args"]["components"]["boot"]
            break
    errs2 = validate_replay_timeline(obj2)
    assert errs2 and "boot" in errs2[0]


def test_diff_runs_skips_failed_and_unmatched():
    a = _run("poisson", seed=0, duration=15.0)
    b = run_config(a.config, trace=a.trace)
    entries = diff_runs(a, b)
    assert all(e["delta"] == 0.0 for e in entries)
    ids = {e["arrival_id"] for e in entries}
    failed = {r.arrival_id for r in a.records if r.failed}
    assert not (ids & failed)
