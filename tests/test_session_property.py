"""Property tests (hypothesis) for the incremental scheduling data plane.

Two invariants carry the whole ``SchedulerSession`` design:

* **delta exactness** — replaying any interleaving of allocate / release /
  add-worker / fail-worker deltas onto ``StateTensors`` yields tensors
  bit-identical to ``StateTensors.from_conf`` of the final conf (the session
  never has to rebuild to stay correct);
* **decision exactness** — a session's decisions against its delta-maintained
  tensors are identical to the scalar Listing-1 reference evaluated on a
  fresh ``conf`` at every step, including the warmth tie-break.
"""
import random

import pytest

try:  # the @given sweep needs hypothesis (CI installs it); the deterministic
    # tests below run everywhere
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import (
    ClusterState,
    Registry,
    SchedulerSession,
    StateTensors,
    try_schedule,
)
from tests.test_batched_equivalence import TAGS, random_script

MEMS = [1.0, 10.0, 30.0, 0.3, 0.7]  # incl. f32-inexact values
CAPS = [20.0, 50.0, 100.0]


if HAS_HYPOTHESIS:
    @st.composite
    def churn_programs(draw):
        """A list of state-mutation op names."""
        n_steps = draw(st.integers(5, 40))
        return [draw(st.sampled_from(["add", "alloc", "release", "fail",
                                      "schedule"]))
                for _ in range(n_steps)]


def _registry(rng: random.Random) -> Registry:
    reg = Registry()
    for t in TAGS:
        reg.register(f"fn_{t}", memory=rng.choice(MEMS), tag=t)
    return reg


def _apply_program(ops, seed):
    """Drives a ClusterState through the program with a session attached;
    returns (state, reg, session, scalar-vs-session decision log)."""
    rng = random.Random(seed)
    script = random_script(rng)
    state = ClusterState()
    reg = _registry(rng)
    session = SchedulerSession(state, reg, script)
    session.tensors()  # build early: every mutation below is a delta
    live = []
    n_workers = 0
    decisions = []
    for op in ops:
        if op == "add" or n_workers == 0:
            state.add_worker(f"w{n_workers}", max_memory=rng.choice(CAPS))
            n_workers += 1
        elif op == "alloc":
            f = f"fn_{rng.choice(TAGS)}"
            workers = state.workers()
            if workers:
                w = rng.choice(workers)
                view = state.conf()[w]
                if view.memory_used + reg[f].memory <= view.max_memory:
                    live.append(state.allocate(f, w, reg).activation_id)
        elif op == "release" and live:
            state.complete(live.pop(rng.randrange(len(live))))
        elif op == "fail" and state.workers():
            gone = rng.choice(state.workers())
            state.fail_worker(gone)
            alive = {a.activation_id for a in state.active_activations()}
            live = [a for a in live if a in alive]
        elif op == "schedule":
            f = f"fn_{rng.choice(TAGS)}"
            r1, r2 = random.Random(seed + 99), random.Random(seed + 99)
            got = session.try_schedule(f, rng=r1)
            want = try_schedule(f, state.conf(), script, reg, rng=r2)
            decisions.append((got, want))
    return state, reg, session, decisions


def _check_program(ops, seed):
    state, reg, session, decisions = _apply_program(ops, seed)
    fresh = StateTensors.from_conf(state.conf(), session.tag_index)
    assert session.tensors().equals(fresh)
    for got, want in decisions:
        assert got == want
    # every mutation flowed through the change feed: no rebuild beyond the
    # initial from_state (workers re-joining their old conf slot excepted,
    # and this program never re-adds a failed worker id)
    assert session.stats["rebuilds"] <= 1


if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(ops=churn_programs(), seed=st.integers(0, 2**16))
    def test_delta_interleavings_equal_fresh_snapshot(ops, seed):
        _check_program(ops, seed)


def test_delta_interleavings_seeded_sweep():
    """hypothesis-free fallback: the same property over seeded random
    programs, so minimal environments still exercise the delta paths."""
    for seed in range(30):
        rng = random.Random(seed * 31)
        ops = [rng.choice(["add", "alloc", "release", "fail", "schedule"])
               for _ in range(rng.randint(5, 40))]
        _check_program(ops, seed)


def test_rejoining_worker_keeps_conf_slot():
    """A worker that fails and re-joins keeps its original conf position —
    the session detects the reuse, invalidates, and rebuilds correctly."""
    state = ClusterState()
    reg = Registry()
    reg.register("fn_a", memory=1.0, tag="a")
    for w in ("w0", "w1", "w2"):
        state.add_worker(w, max_memory=10.0)
    session = SchedulerSession(state, reg)
    session.tensors()
    state.fail_worker("w1")
    assert session.tensors().workers == ("w0", "w2")
    state.add_worker("w1", max_memory=10.0)  # re-join: original slot
    assert tuple(state.conf().keys()) == ("w0", "w1", "w2")
    assert session.tensors().workers == ("w0", "w1", "w2")
    fresh = StateTensors.from_conf(state.conf(), session.tag_index)
    assert session.tensors().equals(fresh)


def test_scratch_wave_leaves_live_tensors_untouched():
    rng = random.Random(7)
    script = random_script(rng)
    state = ClusterState()
    reg = _registry(rng)
    for i in range(4):
        state.add_worker(f"w{i}", max_memory=100.0)
    session = SchedulerSession(state, reg, script)
    before = session.tensors().copy()
    fs = [f"fn_{rng.choice(TAGS)}" for _ in range(10)]
    session.schedule_wave(fs, rng=random.Random(1))  # apply_to=None: scratch
    assert session.tensors().equals(before)
    # and a live wave (apply_to=state) matches the scalar loop exactly
    ref_state = ClusterState()
    for i in range(4):
        ref_state.add_worker(f"w{i}", max_memory=100.0)
    expected = []
    ref_rng = random.Random(2)
    for f in fs:
        w = try_schedule(f, ref_state.conf(), script, reg, rng=ref_rng)
        expected.append(w)
        if w is not None:
            ref_state.allocate(f, w, reg)
    res = session.schedule_wave(fs, rng=random.Random(2), apply_to=state)
    assert res.assignments == expected


def test_session_matches_scalar_on_f32_inexact_memories():
    """The scalar reference compares memory in python floats (f64); the
    session must too.  max_memory=0.9 with three 0.3-memory residents is the
    canonical trap: f32 arithmetic rejects the third allocation that f64
    (and Listing 1) accepts."""
    state = ClusterState()
    reg = Registry()
    reg.register("fn_a", memory=0.3, tag="a")
    state.add_worker("w0", max_memory=0.9)
    from tests.test_batched_equivalence import AAppScript, Block, TagPolicy
    script = AAppScript(policies=(
        TagPolicy(tag="a", blocks=(Block(workers=("*",)),)),))
    session = SchedulerSession(state, reg, script)
    for i in range(3):
        want = try_schedule("fn_a", state.conf(), script, reg)
        got = session.try_schedule("fn_a")
        assert got == want == "w0", (i, got, want)
        state.allocate("fn_a", "w0", reg)
    # full: 0.3*3 sums to 0.8999999999999999 <= 0.9, a 4th does not fit
    assert try_schedule("fn_a", state.conf(), script, reg) is None
    assert session.try_schedule("fn_a") is None


def test_compact_reclaims_dead_tag_columns():
    """Per-session tags accumulate in the append-only index; compact()
    rebuilds it from live state and decisions stay exact."""
    rng = random.Random(3)
    script = random_script(rng)
    state = ClusterState()
    reg = _registry(rng)
    for i in range(3):
        state.add_worker(f"w{i}", max_memory=100.0)
    session = SchedulerSession(state, reg, script)
    for i in range(50):  # churn of short-lived per-session tags
        reg.register(f"kv-{i}", memory=1.0, tag=f"kv:{i}")
        act = state.allocate(f"kv-{i}", "w0", reg)
        session.try_schedule(f"fn_{rng.choice(TAGS)}")
        state.complete(act.activation_id)
    grown = len(session.tag_index)
    assert grown >= 50  # every dead session tag still holds a column
    session.compact()
    assert len(session.tag_index) < grown - 40  # columns reclaimed
    fresh = StateTensors.from_conf(state.conf(), session.tag_index)
    assert session.tensors().equals(fresh)
    r1, r2 = random.Random(9), random.Random(9)
    for _ in range(8):
        f = f"fn_{rng.choice(TAGS)}"
        assert session.try_schedule(f, rng=r1) == \
            try_schedule(f, state.conf(), script, reg, rng=r2)
