"""Loop-aware HLO cost model: validated against XLA on loop-free programs and
against analytic trip counts on scans; collective parser on real lowered HLO."""
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.roofline.flops import analyze
from repro.roofline.hlo import (
    collective_summary,
    computation_multiplicities,
    shape_bytes,
)


def test_shape_bytes():
    assert shape_bytes("bf16[2,1024]") == 2 * 1024 * 2
    assert shape_bytes("f32[]") == 4
    assert shape_bytes("pred[7]") == 7
    assert shape_bytes("s32[3,3]{1,0}") == 36


def test_loop_free_matches_xla():
    def g(x, w):
        return jnp.tanh(x @ w).sum()

    X = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    W = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = jax.jit(g).lower(X, W).compile()
    mine = analyze(c.as_text())
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict], newer returns dict
        ca = ca[0]
    assert abs(mine["flops"] - ca["flops"]) / ca["flops"] < 0.05
    assert abs(mine["bytes"] - ca["bytes accessed"]) / ca["bytes accessed"] < 0.05


def test_scan_trip_count_awareness():
    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    X = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    W = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    c = jax.jit(f).lower(X, W).compile()
    mine = analyze(c.as_text())
    expected = 6 * 2 * 128 ** 3
    assert abs(mine["flops"] - expected) / expected < 0.01
    # XLA's own analysis counts the body once — ours must not
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict], newer returns dict
        ca = ca[0]
    assert ca["flops"] < expected / 2


def test_nested_scan_multiplicities():
    def f(x):
        def inner(c, _):
            return c * 2.0, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    mult = computation_multiplicities(c.as_text())
    assert max(mult.values()) >= 15  # inner body runs 5*3 times


def test_collective_summary_on_sharded_program():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run under dryrun smoke instead)")
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((jax.device_count(),), ("d",))
    X = jax.ShapeDtypeStruct((8, 64), jnp.float32,
                             sharding=NamedSharding(mesh, P("d", None)))

    def f(x):
        return x.sum()

    c = jax.jit(f).lower(X).compile()
    s = collective_summary(c.as_text())
    assert "all-reduce" in s and s["all-reduce"]["count"] >= 1
