"""The v1 call shapes keep working and warn exactly once per process."""
import warnings

import pytest

from repro.core import ClusterState, Registry, parse, schedule, SchedulingFailure
from repro.core import deprecation
from repro.cluster.topology import two_pod_cells
from repro.platform import Platform
from repro.serve.engine import Engine, Request


@pytest.fixture(autouse=True)
def _fresh_warning_state():
    """Each test observes the once-per-process behaviour from a clean slate
    (other suites may already have tripped the shims)."""
    deprecation.reset()
    yield
    deprecation.reset()


def _setup():
    state = ClusterState()
    reg = Registry()
    reg.register("fn", memory=1.0, tag="t")
    for w in ("w0", "w1"):
        state.add_worker(w, max_memory=8.0)
    return state, reg, parse("t:\n  workers: *\n")


def test_core_schedule_keeps_working_and_warns_once():
    state, reg, script = _setup()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert schedule("fn", state.conf(), script, reg) == "w0"
        assert schedule("fn", state.conf(), script, reg) == "w0"
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1  # exactly once
    assert "decide" in str(deps[0].message)
    # the raise-on-failure contract of the v1 shape is preserved
    empty = ClusterState()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(SchedulingFailure):
            schedule("fn", empty.conf(), script, reg)


def test_engine_legacy_shape_keeps_working_and_warns_once():
    cells = two_pod_cells()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = Engine(cells, runner=lambda req, cell: "ok",
                     heartbeat_timeout=1e9, hedge_after=None)
        eng2 = Engine(cells, runner=lambda req, cell: "ok",
                      heartbeat_timeout=1e9, hedge_after=None)
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1  # exactly once across both constructions
    assert "Platform" in str(deps[0].message)
    # ...and the engine built through the shim is fully functional
    eng.deploy("m", ["pod0-cell0"], weights_gb=8)
    comp = eng.submit(Request(model="m", kind="prefill", session="s"))
    assert comp.ok and comp.cell == "pod0-cell0"
    del eng2


def test_engine_platform_shape_does_not_warn():
    cells = two_pod_cells()
    plat = Platform(cluster={n: s.hbm_gb for n, s in cells.items()})
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = Engine(cells, platform=plat, runner=lambda req, cell: "ok",
                     heartbeat_timeout=1e9, hedge_after=None)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert eng.state is plat.state and eng.scheduler is plat.session


def test_engine_platform_shape_rejects_double_attachments():
    cells = two_pod_cells()
    plat = Platform(cluster={n: s.hbm_gb for n, s in cells.items()})
    with pytest.raises(ValueError):
        Engine(cells, platform=plat, forecast=object())
