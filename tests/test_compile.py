"""The v2 compile pipeline: parse -> resolve -> validate -> lower."""
import pytest

from repro.core import (
    AAppError,
    AAppScript,
    Block,
    ClusterState,
    CompileError,
    CompiledScript,
    IR_VERSION,
    Registry,
    SchedulerSession,
    TagPolicy,
    Affinity,
    compile_script,
    parse,
    try_schedule,
)
from repro.core.ast import DEFAULT_TAG
from repro.core.compile import lower, resolve, validate

SCRIPT = """
d:
  workers: *
  strategy: random
  affinity: [!h]
i:
  - workers: *
    strategy: warmest
    affinity: [d]
  - followup: fail
h:
  workers: [w_big]
default:
  workers: *
  strategy: least_loaded
"""


def _reg():
    reg = Registry()
    reg.register("divide", memory=1.0, tag="d")
    reg.register("impera", memory=1.0, tag="i")
    reg.register("heavy", memory=4.0, tag="h")
    return reg


def test_compile_script_end_to_end():
    cs = compile_script(SCRIPT, _reg())
    assert isinstance(cs, CompiledScript)
    assert cs.ir_version == IR_VERSION
    assert cs.source == SCRIPT  # the original text is kept in the IR
    assert cs.script == parse(SCRIPT)
    assert not cs.warnings
    # eager lowering: every tag's rows (incl. default) are ready
    for tag in (*cs.script.tags, DEFAULT_TAG):
        assert cs.policies.rows_for(tag).aff.shape[0] == len(
            cs.resolved[tag].blocks)


def test_resolve_applies_followup_chaining():
    cs = compile_script(SCRIPT, _reg())
    # d: own block + the explicit default block (followup: default)
    assert len(cs.resolved["d"].blocks) == 2
    assert cs.resolved["d"].blocks[1].strategy == "least_loaded"
    # i: followup fail -> no default chain
    assert len(cs.resolved["i"].blocks) == 1
    assert cs.candidate_blocks("i") == cs.resolved["i"].blocks
    # unknown tags fall through to the default chain (APP semantics)
    assert cs.candidate_blocks("nope") == cs.resolved[DEFAULT_TAG].blocks


def test_resolve_synthesizes_absent_default():
    cs = compile_script("t:\n  workers: *\n", _reg())
    assert cs.resolved[DEFAULT_TAG].synthesized
    assert cs.resolved[DEFAULT_TAG].blocks[0].is_wildcard


def test_validate_rejects_unsatisfiable_affinity():
    script = AAppScript(policies=(TagPolicy(tag="t", blocks=(
        Block(workers=("*",),
              affinity=Affinity(affine=("x",), anti_affine=("x",))),)),))
    with pytest.raises(CompileError) as e:
        compile_script(script, _reg())
    assert "unsatisfiable" in str(e.value)
    assert isinstance(e.value, AAppError)  # CompileError is an AAppError


def test_validate_warns_on_unknown_affinity_term():
    cs = compile_script("t:\n  workers: *\n  affinity: [ghost_tag]\n", _reg())
    assert any("ghost_tag" in d.message for d in cs.warnings)
    # known dynamic-ish tags from the registry never warn
    cs2 = compile_script("t:\n  workers: *\n  affinity: [d]\n", _reg())
    assert not cs2.warnings


def test_validate_warns_on_unreachable_blocks():
    text = """
t:
  - workers: *
  - workers: [w1]
"""
    cs = compile_script(text, _reg())
    assert any("unreachable" in d.message for d in cs.warnings)
    # ...but an unconstrained wildcard as the *last* own block is idiomatic
    cs2 = compile_script("t:\n  - workers: [w1]\n  - workers: *\n", _reg())
    assert not any("unreachable" in d.message for d in cs2.warnings)


def test_lower_shares_a_tag_index():
    reg = _reg()
    script = parse(SCRIPT)
    idx, pol = lower(script, reg)
    # script tags + referenced affinity terms, no registry sweep
    assert set(idx.tags) >= {"d", "i", "h"}
    idx2, _ = lower(parse("z:\n  workers: *\n  affinity: [d]\n"), reg,
                    tag_index=idx)
    assert idx2 is idx  # lowered into the shared universe
    assert "z" in idx.index


def test_session_adopts_compiled_script_and_stays_exact():
    reg = _reg()
    cs = compile_script(SCRIPT, reg)
    state = ClusterState()
    for w in ("w0", "w1", "w_big"):
        state.add_worker(w, max_memory=8.0)
    session = SchedulerSession(state, reg, cs)
    # pristine session adopts the compiled universe wholesale
    assert session.tag_index is cs.tag_index
    import random
    r1, r2 = random.Random(5), random.Random(5)
    for f in ("heavy", "divide", "impera", "impera"):
        got = session.try_schedule(f, rng=r1)
        want = try_schedule(f, state.conf(), cs.script, reg, rng=r2)
        assert got == want
        if got is not None:
            state.allocate(f, got, reg)
    session.close()


def test_compile_rejects_non_script_input():
    with pytest.raises(AAppError):
        compile_script(42, _reg())


# --------------------------------------------------------------------------- #
# v3 zone pass: diagnostics + IR version
# --------------------------------------------------------------------------- #


def test_ir_version_is_4():
    from repro.core.compile import IR_VERSION

    assert IR_VERSION == 4
    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    assert compile_script("t:\n  workers: *\n", reg).ir_version == 4


def test_validate_warns_on_unknown_zone_term():
    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    compiled = compile_script(
        "t:\n  workers: *\n  affinity: [zone:mars]\n", reg,
        zones=("eu", "us"))
    assert any("matches no configured zone" in d.message
               for d in compiled.warnings)
    # without a configured zone set the same script compiles silently
    # (dynamic platforms may grow zones later)
    clean = compile_script("t:\n  workers: *\n  affinity: [zone:mars]\n", reg)
    assert not any("configured zone" in d.message for d in clean.warnings)


def test_validate_rejects_zone_unsatisfiable_blocks():
    from repro.core.ast import Affinity, Block, TagPolicy, AAppScript

    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    both = AAppScript(policies=(TagPolicy(tag="t", blocks=(
        Block(workers=("*",),
              affinity=Affinity(zones=("eu",), anti_zones=("eu",))),)),))
    with pytest.raises(CompileError) as ei:
        compile_script(both, reg)
    assert "zone-unsatisfiable" in str(ei.value)
    two = AAppScript(policies=(TagPolicy(tag="t", blocks=(
        Block(workers=("*",), affinity=Affinity(zones=("eu", "us"))),)),))
    with pytest.raises(CompileError) as ei:
        compile_script(two, reg)
    assert "exactly one zone" in str(ei.value)


# --------------------------------------------------------------------------- #
# v4 analysis section: back-compat, require_ir, deterministic ordering
# --------------------------------------------------------------------------- #


def test_v4_products_carry_an_analysis_section():
    cs = compile_script(SCRIPT, _reg())
    assert cs.analysis is not None
    rows = {t.tag: t for t in cs.analysis.tags}
    assert rows["i"].chain == ("i", "d")  # transitive affinity anchors
    assert cs.analysis.workers_analysed == 0  # no cluster shape given


def test_old_scripts_compile_with_zero_new_diagnostics():
    # the v3 zone-era script, untouched: the v4 passes must stay silent
    cs = compile_script(SCRIPT, _reg())
    assert cs.diagnostics == ()
    # ... even with a cluster shape, when everything is placeable
    cs = compile_script(SCRIPT, _reg(),
                        workers={"w_big": 8.0, "w1": 8.0, "w2": 8.0})
    assert [d for d in cs.diagnostics if d.severity == "error"] == []


def test_require_ir_rejects_version_pinned_consumers():
    from repro.core import require_ir

    cs = compile_script(SCRIPT, _reg())
    require_ir(cs)  # current version: fine
    with pytest.raises(CompileError) as ei:
        require_ir(cs, 3)
    assert "v3" in str(ei.value) and "v4" in str(ei.value)
    assert ei.value.diagnostics[0].code == "ir-version"


def test_unplaceable_chain_is_a_compile_error():
    # no worker fits heavy (4.0), and the affine i+d pair (2.0) cannot
    # co-reside on a 1.5 worker — even through the default fallback chain
    reg = _reg()
    with pytest.raises(CompileError) as ei:
        compile_script(SCRIPT, reg, workers={"w0": 1.5, "w1": 1.5})
    codes = {(d.tag, d.code) for d in ei.value.diagnostics}
    assert ("h", "unplaceable-chain") in codes
    assert ("i", "unplaceable-chain") in codes


def test_diagnostics_sort_deterministically():
    from repro.core import Diagnostic, diagnostic_sort_key, sort_diagnostics

    ds = [
        Diagnostic("warning", "b", "m2", code="c", block=1),
        Diagnostic("error", "z", "m0"),
        Diagnostic("warning", "b", "m1", code="c", block=0),
        Diagnostic("warning", "a", "m3"),
    ]
    got = sort_diagnostics(ds)
    assert [d.severity for d in got] == ["error", "warning", "warning",
                                         "warning"]
    assert [d.message for d in got] == ["m0", "m3", "m1", "m2"]
    assert sort_diagnostics(tuple(reversed(ds))) == got
    assert diagnostic_sort_key(got[0])[0] == 0
