"""Per-arch smoke tests (reduced configs): forward/train-step shapes + no
NaNs, decode parity paths, attention-impl and SSM-path equivalences."""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data.pipeline import make_batch
from repro.models import (
    init_cache,
    init_model,
    model_decode_step,
    model_forward,
    model_loss,
)
from repro.models.transformer import merge_decode_buffer
from repro.optim import adamw
from repro.train.step import make_train_step

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, B=2, S=64):
    return {k: jnp.asarray(v) for k, v in make_batch(cfg, B, S, 0).items()}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch):
    cfg = ARCHS[arch].reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    hidden = model_forward(cfg, params, batch)
    assert hidden.ndim == 3 and hidden.shape[-1] == cfg.d_model
    assert bool(jnp.isfinite(hidden.astype(jnp.float32)).all())
    loss = model_loss(cfg, params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), remat="none")
    params = init_model(cfg, jax.random.PRNGKey(0))
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw.init(ocfg, params)
    step = jax.jit(make_train_step(cfg, ocfg))
    p2, o2, metrics = step(params, opt, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                                            - b.astype(jnp.float32)))),
                         params, p2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step(arch):
    cfg = ARCHS[arch].reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    B = 2
    if cfg.family == "encdec":
        from repro.models.encdec import encode, encdec_prefill_cache
        batch = _batch(cfg)
        enc_out = encode(cfg, params, batch["frames"])
        cache = encdec_prefill_cache(cfg, params, enc_out, B, 32)
    else:
        cache = init_cache(cfg, B, 64)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = model_decode_step(cfg, params, cache, tok)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    logits2, _ = model_decode_step(cfg, params, cache, tok)
    assert bool(jnp.isfinite(logits2).all())


def test_attention_impls_agree():
    cfg = ARCHS["starcoder2-15b"].reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, B=1, S=128)
    outs = {}
    for impl in ("direct", "chunked", "chunked2d"):
        c = dataclasses.replace(cfg, attn_impl=impl, attn_chunk=32, attn_q_block=32)
        # force the chunked paths even for small shapes
        from repro.models import transformer as tf
        outs[impl] = tf.lm_forward(c, params, batch, impl=impl)
    # direct path triggers below the size threshold; compare finite + close
    a = outs["chunked"].astype(jnp.float32)
    b = outs["chunked2d"].astype(jnp.float32)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-2


def test_gemma_local_global_tail():
    full = ARCHS["gemma3-4b"]
    assert full.n_tail == 4  # 34 = 5*6 + 4
    # run a reduced config WITH a tail (13 layers = 2 periods + 1)
    cfg = dataclasses.replace(full.reduced(), n_layers=13)
    params = init_model(cfg, jax.random.PRNGKey(0))
    assert "tail" in params and len(params["tail"]) == 1
    batch = _batch(cfg)
    assert np.isfinite(float(model_loss(cfg, params, batch)))
    cache = init_cache(cfg, 2, 64)
    logits, _ = model_decode_step(cfg, params, cache, jnp.zeros((2, 1), jnp.int32))
    assert bool(jnp.isfinite(logits).all())


def test_buffered_decode_equals_legacy_across_merge():
    cfg0 = ARCHS["qwen1.5-32b"].reduced()
    cfgB = dataclasses.replace(cfg0, decode_buffer=4)
    params = init_model(cfg0, jax.random.PRNGKey(0))
    B, T = 2, 9
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg0.vocab)
    c0, cB = init_cache(cfg0, B, 32), init_cache(cfgB, B, 32)
    for t in range(T):
        l0, c0 = model_decode_step(cfg0, params, c0, toks[:, t:t + 1])
        lB, cB = model_decode_step(cfgB, params, cB, toks[:, t:t + 1])
        assert float(jnp.max(jnp.abs(l0 - lB))) < 1e-3, t
        if (t + 1) % 4 == 0:
            cB = merge_decode_buffer(cfgB, cB)


def test_ssm_unchunked_equals_chunked():
    cfg = ARCHS["falcon-mamba-7b"].reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l_c = float(model_loss(cfg, params, batch))
    l_u = float(model_loss(dataclasses.replace(cfg, scan_chunk=0), params, batch))
    assert abs(l_c - l_u) < 1e-4


def test_prefix_decode_consistency():
    """Teacher-forced forward logits == step-by-step decode logits."""
    cfg = ARCHS["qwen3-moe-30b-a3b"].reduced()
    # MoE capacity drops depend on batch grouping; use the dense-ish check arch
    cfg = ARCHS["starcoder2-15b"].reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, T = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0, cfg.vocab)
    hidden = model_forward(cfg, params, {"tokens": toks})
    from repro.models.transformer import lm_logits
    full = lm_logits(cfg, params, hidden)  # [B,T,V]
    cache = init_cache(cfg, B, T + 4)
    for t in range(T):
        step_logits, cache = model_decode_step(cfg, params, cache, toks[:, t:t + 1])
        err = float(jnp.max(jnp.abs(step_logits - full[:, t])))
        assert err < 2e-2, (t, err)
