"""Serving engine (aAPP placement, failover, hedging) + §V simulator."""
import dataclasses

import pytest

from repro.cluster.divide_impera import DivideImperaWorkload
from repro.cluster.simulator import ClusterSim, SimParams
from repro.cluster.topology import CellSpec, paper_testbed, two_pod_cells
from repro.core import parse, try_schedule
from repro.serve.engine import Engine, Request


def make_engine(latency=0.01, hedge_after=None):
    t = [0.0]

    def clock():
        return t[0]

    slow_cells = set()

    def runner(req, cell):
        dt = 0.5 if cell in slow_cells else latency
        t[0] += dt
        return f"{req.kind}@{cell}"

    eng = Engine(two_pod_cells(), runner=runner, clock=clock,
                 heartbeat_timeout=1e9, hedge_after=hedge_after)
    return eng, t, slow_cells


def test_session_affinity_and_residency():
    eng, _, _ = make_engine()
    eng.deploy("m1", ["pod0-cell0", "pod0-cell1"], weights_gb=8)
    c = eng.submit(Request(model="m1", kind="prefill", session="s"))
    assert c.ok and c.cell in ("pod0-cell0", "pod0-cell1")
    home = eng.session_cell("s")
    for _ in range(5):
        d = eng.submit(Request(model="m1", kind="decode", session="s"))
        assert d.cell == home  # KV affinity pins decode


def test_decode_anti_affine_with_train():
    eng, _, _ = make_engine()
    eng.deploy("m1", ["pod0-cell0", "pod0-cell1"], weights_gb=8)
    tr = eng.submit(Request(model="", kind="train"))
    assert tr.ok
    p = eng.submit(Request(model="m1", kind="prefill", session="s"))
    d = eng.submit(Request(model="m1", kind="decode", session="s"))
    assert d.cell != tr.cell  # isolation


def test_failover_rehomes_sessions():
    eng, _, _ = make_engine()
    eng.deploy("m1", ["pod0-cell0", "pod0-cell1"], weights_gb=8)
    eng.submit(Request(model="m1", kind="prefill", session="s"))
    home = eng.session_cell("s")
    moved = eng.fail_cell(home)
    assert moved == ["s"]
    new_home = eng.session_cell("s")
    assert new_home is not None and new_home != home
    d = eng.submit(Request(model="m1", kind="decode", session="s"))
    assert d.ok and d.cell == new_home


def test_heartbeat_failure_detection():
    eng, t, _ = make_engine()
    eng.heartbeat_timeout = 5.0
    eng.deploy("m1", ["pod0-cell0"], weights_gb=8)
    eng.submit(Request(model="m1", kind="prefill", session="s"))
    t[0] += 100.0
    for c in eng.cells:
        if c != "pod0-cell0":
            eng.heartbeat(c)
    dead = eng.check_health()
    assert "pod0-cell0" in dead


def test_straggler_hedging():
    eng, t, slow = make_engine(hedge_after=0.1)
    eng.deploy("m1", list(eng.cells)[:3], weights_gb=8)
    eng.submit(Request(model="m1", kind="prefill", session="s"))
    slow.add(eng.session_cell("s"))
    d = eng.submit(Request(model="m1", kind="decode", session="s"))
    assert d.ok and d.hedge_won  # the duplicate on another cell finished first


def test_elastic_add_and_drain():
    eng, _, _ = make_engine()
    eng.deploy("m1", ["pod0-cell0"], weights_gb=8)
    eng.submit(Request(model="m1", kind="prefill", session="s"))
    eng.add_cell(CellSpec("pod2-cell0", "pod2", 64, 1024.0))
    assert "pod2-cell0" in eng.state.workers()
    eng.drain_cell("pod0-cell0")
    assert "pod0-cell0" not in eng.state.workers()


# --------------------------------------------------------------------------- #
# §V simulator
# --------------------------------------------------------------------------- #


def _run_case(script_text, seed=0, runs=2, divides=5):
    script = parse(script_text)
    sim = ClusterSim(paper_testbed(), SimParams(), seed=seed)
    import random
    rng = random.Random(seed)
    wl = DivideImperaWorkload(
        sim, lambda f: try_schedule(f, sim.state.conf(), script, sim.registry, rng=rng))

    def start_run(i):
        if i >= runs:
            return
        done = {"h": 0, "d": 0}

        def nxt():
            if done["h"] == 2 and done["d"] == divides:
                start_run(i + 1)

        def hd():
            done["h"] += 1
            nxt()

        wl.submit_heavy("heavy_eu", hd)
        wl.submit_heavy("heavy_us", hd)

        def dd(_):
            done["d"] += 1
            if done["d"] < divides:
                wl.submit_divide(dd)
            else:
                nxt()

        wl.submit_divide(dd)

    start_run(0)
    sim.run()
    return wl.results


from benchmarks.affinity_case_study import AAPP_SCRIPT, ANTI_ONLY_SCRIPT, APP_SCRIPT


def test_aapp_colocates_and_never_retries():
    results = _run_case(AAPP_SCRIPT)
    assert results, "no divides completed"
    for r in results:
        assert not r.failed
        assert r.retries == 0  # same worker => same zone => no EC wait
        assert all(w == r.worker for w in r.impera_workers)  # affinity co-location
        assert r.worker not in ("workereu1", "workerus1")  # anti-affinity vs heavy


def test_app_suffers_retries_or_contention():
    import statistics
    aapp = [r.latency for r in _run_case(AAPP_SCRIPT, seed=1, runs=3, divides=8)]
    app_res = _run_case(APP_SCRIPT, seed=1, runs=3, divides=8)
    app = [r.latency for r in app_res if not r.failed]
    assert statistics.mean(app) > statistics.mean(aapp)
    # under plain APP some functions land on the heavy (small) workers
    assert any(r.worker in ("workereu1", "workerus1") or
               any(w in ("workereu1", "workerus1") for w in r.impera_workers)
               for r in app_res)


def test_eventual_consistency_mechanism():
    sim = ClusterSim(paper_testbed(), SimParams(sync_lag_median=10.0,
                                                sync_lag_sigma=0.01), seed=0)
    sim.db_write("idx", "workereu2", 50)  # written in EU
    assert sim.db_visible("idx", "workereu3", 50)  # same zone: immediate
    assert not sim.db_visible("idx", "workerus2", 50)  # cross-zone: lagged
    sim.now += 1e6
    assert sim.db_visible("idx", "workerus2", 50)  # eventually consistent
