"""Property-based tests (hypothesis) over the scheduling invariants."""
import random

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    AAppScript,
    Affinity,
    Block,
    ClusterState,
    CompiledPolicies,
    Invalidate,
    Registry,
    TagPolicy,
    schedule_wave,
    try_schedule,
)
from repro.core.scheduler import candidate_blocks, valid

TAGS = ["a", "b", "c", "d"]


@st.composite
def scripts(draw):
    policies = []
    for tag in TAGS:
        blocks = []
        for _ in range(draw(st.integers(1, 3))):
            wildcard = draw(st.booleans())
            if wildcard:
                workers = ("*",)
            else:
                ids = draw(st.lists(
                    st.sampled_from([f"w{i}" for i in range(8)] + ["ghost"]),
                    min_size=1, max_size=4, unique=True))
                workers = tuple(ids)
            aff, anti = [], []
            for t in TAGS:
                r = draw(st.integers(0, 5))
                if r == 0:
                    aff.append(t)
                elif r == 1:
                    anti.append(t)
            blocks.append(Block(
                workers=workers,
                strategy=draw(st.sampled_from(["best_first", "any"])),
                invalidate=Invalidate(
                    capacity_used=draw(st.sampled_from([None, 40.0, 80.0])),
                    max_concurrent_invocations=draw(st.sampled_from([None, 1, 4])),
                ),
                affinity=Affinity(affine=tuple(aff), anti_affine=tuple(anti)),
            ))
        policies.append(TagPolicy(tag=tag, blocks=tuple(blocks),
                                  followup=draw(st.sampled_from(["default", "fail"]))))
    return AAppScript(policies=tuple(policies))


@st.composite
def cluster(draw):
    n = draw(st.integers(1, 8))
    state = ClusterState()
    reg = Registry()
    for i in range(n):
        state.add_worker(f"w{i}", max_memory=draw(st.sampled_from([20.0, 50.0, 100.0])))
    for t in TAGS:
        reg.register(f"fn_{t}", memory=draw(st.sampled_from([1.0, 10.0, 30.0])), tag=t)
    for _ in range(draw(st.integers(0, 10))):
        w = f"w{draw(st.integers(0, n - 1))}"
        f = f"fn_{draw(st.sampled_from(TAGS))}"
        view = state.conf()[w]
        if view.memory_used + reg[f].memory <= view.max_memory:
            state.allocate(f, w, reg)
    return state, reg


@settings(max_examples=60, deadline=None)
@given(scripts(), cluster(), st.integers(0, 2**31 - 1))
def test_schedule_returns_valid_worker_or_none_exists(script, clus, seed):
    state, reg = clus
    conf = state.conf()
    for t in TAGS:
        f = f"fn_{t}"
        w = try_schedule(f, conf, script, reg, rng=random.Random(seed))
        blocks = candidate_blocks(t, script)
        if w is None:
            # failure implies NO worker is valid under ANY candidate block
            for b in blocks:
                ids = conf.keys() if b.is_wildcard else b.workers
                assert not any(valid(f, x, conf, reg, b) for x in ids)
        else:
            # the chosen worker is valid under at least one candidate block
            assert any(
                valid(f, w, conf, reg, b)
                and (b.is_wildcard or w in b.workers)
                for b in blocks
            )


@settings(max_examples=40, deadline=None)
@given(scripts(), cluster(), st.integers(0, 2**31 - 1),
       st.lists(st.sampled_from(TAGS), min_size=1, max_size=12))
def test_batched_wave_equals_sequential_reference(script, clus, seed, tags):
    state, reg = clus
    fs = [f"fn_{t}" for t in tags]

    # sequential reference on a private copy of the state
    ref_state = ClusterState()
    for w, view in state.conf().items():
        ref_state.add_worker(w, max_memory=view.max_memory)
    for act in state.active_activations():
        ref_state.allocate(act.function, act.worker, reg)
    rng = random.Random(seed)
    expected = []
    for f in fs:
        w = try_schedule(f, ref_state.conf(), script, reg, rng=rng)
        expected.append(w)
        if w is not None:
            ref_state.allocate(f, w, reg)

    pol = CompiledPolicies(script, reg)
    res = schedule_wave(fs, state.conf(), pol, reg, rng=random.Random(seed),
                        backend="ref")
    assert res.assignments == expected
