"""Zone-sharded control plane tests.

The acceptance property: a :class:`ShardedSession` is **bit-identical** to
the flat :class:`SchedulerSession` whenever the cluster has a single zone
or the script carries no zone terms / topology hints (the router delegates)
— hypothesis-swept plus a seeded hypothesis-free fallback.  On top of that:
zone-term semantics on the flat path vs the scalar reference, the two-level
router's ordering strategies, the partitioned change feed, the N-zone
simulator matrix, and the multi-region trace scenario.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import (
    AAppScript,
    Affinity,
    Block,
    ClusterState,
    Registry,
    SchedulerSession,
    ShardedSession,
    TagPolicy,
    parse,
    try_schedule,
    zone_plan,
)
from repro.core.decision import REASON_ZONE_EXHAUSTED, REASON_ZONE_MASK
from tests.test_batched_equivalence import TAGS, random_script

ZONES = ("eu", "us", "ap")
MEMS = [1.0, 10.0, 30.0, 0.3]
CAPS = [20.0, 50.0, 100.0]


def _registry(rng: random.Random) -> Registry:
    reg = Registry()
    for t in TAGS:
        reg.register(f"fn_{t}", memory=rng.choice(MEMS), tag=t)
    return reg


def _zone_script(rng: random.Random) -> AAppScript:
    """random_script + random zone terms / topology hints injected."""
    base = random_script(rng)
    policies = []
    for p in base.policies:
        blocks = []
        for b in p.blocks:
            zones, anti = (), ()
            r = rng.random()
            if r < 0.3:
                zones = (rng.choice(ZONES),)
            elif r < 0.5:
                anti = tuple(rng.sample(ZONES, rng.randint(1, 2)))
            topo = rng.choice([None, None, "local_first",
                               "least_loaded_zone"])
            blocks.append(Block(
                workers=b.workers, strategy=b.strategy,
                invalidate=b.invalidate,
                affinity=Affinity(affine=b.affinity.affine,
                                  anti_affine=b.affinity.anti_affine,
                                  zones=zones, anti_zones=anti),
                topology=topo))
        policies.append(TagPolicy(tag=p.tag, blocks=tuple(blocks),
                                  followup=p.followup))
    return AAppScript(policies=tuple(policies))


def _churn_program(rng: random.Random, n_lo=5, n_hi=40):
    return [rng.choice(["add", "alloc", "release", "fail", "schedule"])
            for _ in range(rng.randint(n_lo, n_hi))]


def _run_program(ops, seed, *, zones, script):
    """Drive one ClusterState with both sessions attached; compare every
    scheduling decision bit for bit (same rng seeds)."""
    rng = random.Random(seed)
    state = ClusterState()
    reg = _registry(rng)
    flat = SchedulerSession(state, reg, script)
    sharded = ShardedSession(state, reg, script)
    live = []
    n_workers = 0
    origin_cycle = 0
    for op in ops:
        if op == "add" or n_workers == 0:
            z = zones[n_workers % len(zones)] if zones else None
            state.add_worker(f"w{n_workers}", max_memory=rng.choice(CAPS),
                             zone=z)
            n_workers += 1
        elif op == "alloc":
            f = f"fn_{rng.choice(TAGS)}"
            workers = state.workers()
            if workers:
                w = rng.choice(workers)
                view = state.conf()[w]
                if view.memory_used + reg[f].memory <= view.max_memory:
                    live.append(state.allocate(f, w, reg).activation_id)
        elif op == "release" and live:
            state.complete(live.pop(rng.randrange(len(live))))
        elif op == "fail" and state.workers():
            state.fail_worker(rng.choice(state.workers()))
            alive = {a.activation_id for a in state.active_activations()}
            live = [a for a in live if a in alive]
        elif op == "schedule":
            f = f"fn_{rng.choice(TAGS)}"
            origin = (zones[origin_cycle % len(zones)]
                      if zones and rng.random() < 0.5 else None)
            origin_cycle += 1
            r1, r2 = random.Random(seed + 7), random.Random(seed + 7)
            got = sharded.try_schedule(f, rng=r1, origin_zone=origin)
            want = flat.try_schedule(f, rng=r2)
            assert got == want, (f, origin, got, want)
    flat.close()
    sharded.close()


def _check_delegation(seed):
    """No zone terms -> sharded == flat on a multi-zone cluster (the
    acceptance property), and single zone -> identical even WITH zone
    terms and hints."""
    rng = random.Random(seed)
    _run_program(_churn_program(rng), seed, zones=ZONES,
                 script=random_script(random.Random(seed)))
    rng2 = random.Random(seed + 1)
    _run_program(_churn_program(rng2), seed + 1, zones=("solo",),
                 script=_zone_script(random.Random(seed + 1)))


if HAS_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_sharded_bit_identical_property(seed):
        _check_delegation(seed)


def test_sharded_bit_identical_seeded_sweep():
    for seed in range(25):
        _check_delegation(seed * 13)


def test_zone_terms_flat_session_matches_scalar():
    """Zone-constrained scripts on the *flat* data plane: the vectorized
    wmask path must agree with the scalar reference's zone checks."""
    for seed in range(25):
        rng = random.Random(seed * 31 + 5)
        script = _zone_script(rng)
        state = ClusterState()
        reg = _registry(rng)
        n = rng.randint(1, 8)
        for i in range(n):
            state.add_worker(f"w{i}", max_memory=rng.choice(CAPS),
                             zone=rng.choice(ZONES))
        for _ in range(rng.randint(0, 8)):
            w = f"w{rng.randrange(n)}"
            f = f"fn_{rng.choice(TAGS)}"
            view = state.conf()[w]
            if view.memory_used + reg[f].memory <= view.max_memory:
                state.allocate(f, w, reg)
        session = SchedulerSession(state, reg, script)
        for _ in range(6):
            f = f"fn_{rng.choice(TAGS)}"
            r1, r2 = random.Random(seed + 3), random.Random(seed + 3)
            got = session.try_schedule(f, rng=r1)
            want = try_schedule(f, state.conf(), script, reg, rng=r2)
            assert got == want, (seed, f, got, want)
        session.close()


# --------------------------------------------------------------------------- #
# router semantics
# --------------------------------------------------------------------------- #


def _three_zone_state(reg, per_zone=2, mem=10.0):
    state = ClusterState()
    for zi, z in enumerate(ZONES):
        for i in range(per_zone):
            state.add_worker(f"{z}{i}", max_memory=mem, zone=z)
    return state


def test_local_first_prefers_origin_zone():
    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    state = _three_zone_state(reg)
    script = parse("t:\n  workers: *\n  topology: local_first\n")
    ss = ShardedSession(state, reg, script)
    assert ss.try_schedule("f", origin_zone="us") == "us0"
    assert ss.try_schedule("f", origin_zone="ap") == "ap0"
    # no origin: stable zone order (first zone)
    assert ss.try_schedule("f") == "eu0"
    ss.close()


def test_router_spills_when_local_zone_exhausted():
    reg = Registry()
    reg.register("f", memory=8.0, tag="t")
    state = ClusterState()
    state.add_worker("eu0", max_memory=10.0, zone="eu")
    state.add_worker("us0", max_memory=10.0, zone="us")
    script = parse("t:\n  workers: *\n  topology: local_first\n")
    ss = ShardedSession(state, reg, script)
    state.allocate("f", "us0", reg)  # us is now full for another f (8+8>10)
    assert ss.try_schedule("f", origin_zone="us") == "eu0"  # spilled
    d = ss.explain("f", origin_zone="us")
    assert d.worker == "eu0"
    reasons = [v.reason for bt in d.trace for v in bt.workers]
    assert REASON_ZONE_EXHAUSTED in reasons
    ss.close()


def test_zone_terms_restrict_and_trace_zone_mask():
    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    state = _three_zone_state(reg)
    script = parse("t:\n  workers: *\n  affinity: [zone:us]\n")
    ss = ShardedSession(state, reg, script)
    # even with an eu origin hint, the block only admits us
    assert ss.try_schedule("f", origin_zone="eu") == "us0"
    d = ss.explain("f", origin_zone="eu")
    reasons = [v.reason for bt in d.trace for v in bt.workers]
    assert REASON_ZONE_MASK in reasons
    ss.close()


def test_block_priority_beats_zone_locality():
    """Listing-1 block order stays primary: a lower block is only reached
    when every zone of the earlier block is exhausted."""
    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    state = _three_zone_state(reg)
    script = parse(
        "t:\n"
        "  - workers: *\n"
        "    affinity: [zone:eu]\n"
        "  - workers: *\n"
        "    affinity: [zone:us]\n"
        "  - followup: fail\n")
    ss = ShardedSession(state, reg, script)
    # origin us cannot jump the queue: block 0 (eu) wins while eu has room
    assert ss.try_schedule("f", origin_zone="us") == "eu0"
    ss.close()


def test_least_loaded_zone_ordering():
    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    state = _three_zone_state(reg, mem=100.0)
    script = parse("t:\n  workers: *\n  topology: least_loaded_zone\n")
    ss = ShardedSession(state, reg, script)
    for _ in range(3):
        state.allocate("f", "eu0", reg)
    for _ in range(1):
        state.allocate("f", "us0", reg)
    # loads: eu=3, us=1, ap=0 -> ap first
    assert ss.try_schedule("f") == "ap0"
    ss.close()


class _FakePool:
    """warmth_row/warmth shaped like WarmPool, over a fixed table."""

    def __init__(self, rows):
        self.rows = rows

    def warmth_row(self, function, now):
        return self.rows.get(function, {})

    def warmth(self, function, worker, now):
        return self.rows.get(function, {}).get(worker, 0)


def test_warmest_zone_ordering():
    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    state = _three_zone_state(reg, mem=100.0)
    pool = _FakePool({"f": {"ap0": 2, "ap1": 2, "us0": 1}})
    script = parse("t:\n  workers: *\n  topology: warmest_zone\n")
    ss = ShardedSession(state, reg, script, pool=pool)
    # zone warmth rollups: ap=4, us=1, eu=0 -> ap first
    assert ss.try_schedule("f") == "ap0"
    ss.close()


def test_unschedulable_routed_tag_returns_none():
    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    state = _three_zone_state(reg)
    script = parse("t:\n  workers: *\n  affinity: [zone:nowhere]\n"
                   "  followup: fail\n")
    ss = ShardedSession(state, reg, script)
    assert ss.try_schedule("f") is None
    d = ss.explain("f")
    assert d.worker is None
    ss.close()


# --------------------------------------------------------------------------- #
# partitioned change feed
# --------------------------------------------------------------------------- #


def test_shards_only_see_their_zone_deltas():
    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    state = _three_zone_state(reg)
    script = parse("t:\n  workers: *\n  topology: local_first\n")
    ss = ShardedSession(state, reg, script)
    # build all three shards
    for z in ZONES:
        ss.try_schedule("f", origin_zone=z)
    eu_v = state.zone_version("eu")
    us_deltas = ss._shards["us"].stats["deltas"]
    # churn entirely inside eu
    acts = [state.allocate("f", "eu0", reg) for _ in range(4)]
    for a in acts:
        state.complete(a.activation_id)
    assert state.zone_version("eu") == eu_v + 8
    assert ss._shards["us"].stats["deltas"] == us_deltas  # untouched
    assert ss._shards["eu"].stats["deltas"] >= 8
    # and the eu shard tracked without a rebuild
    rebuilds = ss._shards["eu"].stats["rebuilds"]
    ss.try_schedule("f", origin_zone="eu")
    assert ss._shards["eu"].stats["rebuilds"] == rebuilds
    ss.close()


def test_set_zones_rezones_and_sessions_follow():
    reg = Registry()
    reg.register("f", memory=1.0, tag="t")
    state = ClusterState()
    state.add_worker("w0", max_memory=10.0, zone="eu")
    state.add_worker("w1", max_memory=10.0, zone="eu")
    script = parse("t:\n  workers: *\n  affinity: [zone:us]\n"
                   "  followup: fail\n")
    ss = ShardedSession(state, reg, script)
    flat = SchedulerSession(state, reg, script)
    assert flat.try_schedule("f") is None  # nothing in us yet
    state.set_zones({"w1": "us"})
    assert state.zone_of("w1") == "us"
    assert flat.try_schedule("f") == "w1"
    assert ss.try_schedule("f") == "w1"
    ss.close()
    flat.close()


# --------------------------------------------------------------------------- #
# compile-pass plan
# --------------------------------------------------------------------------- #


def test_zone_plan_masks_and_scripts():
    script = parse(
        "t:\n"
        "  - workers: *\n"
        "    affinity: [zone:eu, x]\n"
        "  - workers: *\n"
        "    affinity: [!zone:ap]\n"
        "  - followup: fail\n")
    plan = zone_plan(script, ZONES)
    assert plan.routed("t") and not plan.routed("unknown-tag") \
        or plan.routed("unknown-tag") is plan.routed("default")
    m = plan.mask("t")
    assert m.shape == (2, 3)
    assert list(m[0]) == [True, False, False]  # zone:eu
    assert list(m[1]) == [True, True, False]  # !zone:ap
    # per-zone scripts: stripped terms, fail followup, poisoned empty chains
    eu = plan.zone_scripts["eu"]["t"]
    assert len(eu.blocks) == 2 and eu.followup == "fail"
    assert eu.blocks[0].affinity.zones == ()
    assert eu.blocks[0].affinity.affine == ("x",)
    ap = plan.zone_scripts["ap"]["t"]
    assert len(ap.blocks) == 1  # poisoned: no admissible block
    assert ap.blocks[0].workers[0].startswith("__zone-unsatisfiable")
    assert plan.pos("t", "us", 0) == -1 and plan.pos("t", "us", 1) == 0


# --------------------------------------------------------------------------- #
# platform integration
# --------------------------------------------------------------------------- #


def test_platform_zones_transparent_sharding():
    from repro.platform import Platform

    plat = Platform(
        "t:\n  workers: *\n  topology: local_first\n",
        cluster={"eu0": 8.0, "eu1": 8.0, "us0": 8.0},
        zones={"eu0": "eu", "eu1": "eu", "us0": "us"},
        functions={"f": (1.0, "t")})
    assert plat._sharded
    assert isinstance(plat.session, ShardedSession)
    d = plat.invoke("f", zone="us")
    assert d.worker == "us0"
    stats = plat.stats()
    assert set(stats["zones"]) == {"eu", "us"}
    assert stats["zones"]["us"]["load"] == 1
    plat.complete(d)
    # placer accepts the zone keyword
    placer = plat.placer(random.Random(0))
    assert placer("f", zone="us") == "us0"
    assert placer("f") == "eu0"
    plat.close()


def test_platform_single_zone_stays_flat():
    from repro.platform import Platform

    plat = Platform("t:\n  workers: *\n",
                    cluster={"w0": 8.0}, zones={"w0": "eu"},
                    functions={"f": (1.0, "t")})
    assert not plat._sharded
    assert isinstance(plat.session, SchedulerSession)
    plat.close()


def test_platform_compile_warns_on_unknown_zone():
    from repro.platform import Platform

    plat = Platform(
        "t:\n  workers: *\n  affinity: [zone:mars]\n",
        cluster={"a0": 8.0, "b0": 8.0},
        zones={"a0": "eu", "b0": "us"},
        functions={"f": (1.0, "t")})
    assert any("matches no configured zone" in d.message
               for d in plat.diagnostics)
    plat.close()


# --------------------------------------------------------------------------- #
# N-zone simulator + multi-region trace
# --------------------------------------------------------------------------- #


def test_simulator_nzone_replication_and_overhead():
    from repro.cluster.simulator import ClusterSim, SimParams
    from repro.cluster.topology import ZoneTopology, multizone_testbed

    topo = ZoneTopology(zones=ZONES, overhead={"us": 0.2, "ap": 0.4},
                        lag_factor={("eu", "ap"): 3.0})
    sim = ClusterSim(multizone_testbed(ZONES), SimParams(), seed=0,
                     topology=topo)
    assert sim.overhead("workereu1") == pytest.approx(0.05)
    assert sim.overhead("workerus1") == pytest.approx(0.25)
    assert sim.overhead("workerap1") == pytest.approx(0.45)
    sim.db_write("idx", "workereu1", 10)
    doc = sim._docs["idx"][0]
    assert doc["eu"] == 0.0
    lag_us = doc["us"]
    assert doc["ap"] == pytest.approx(3.0 * lag_us)  # lag factor applied
    # visibility respects per-zone convergence
    assert sim.db_visible("idx", "workereu2", 10)
    sim.now = lag_us - 1e-9
    assert not sim.db_visible("idx", "workerus2", 10) or lag_us == 0.0
    sim.now = doc["ap"] + 1e-6
    assert sim.db_visible("idx", "workerap2", 10)
    # cross-zone front-door routing only for zone-stamped requests
    assert sim.route_cost(None, "workerus1") == 0.0
    assert sim.route_cost("us", "workerus1") == 0.0
    assert sim.route_cost("eu", "workerus1") == SimParams().cross_zone_route


def test_simulator_default_topology_matches_seed_behavior():
    from repro.cluster.simulator import ClusterSim, SimParams
    from repro.cluster.topology import paper_testbed

    sim = ClusterSim(paper_testbed(), SimParams(), seed=0)
    assert sim.topology.control_zone == "eu"
    assert sim.overhead("workereu1") == pytest.approx(0.05)
    assert sim.overhead("workerus1") == pytest.approx(0.05 + 0.35)
    sim.db_write("i", "workereu1", 5)
    doc = sim._docs["i"][0]
    assert set(doc) == {"n", "eu", "us"} and doc["us"] > doc["eu"]
    # the sim state carries worker zones (the shared zone protocol)
    assert sim.state.zone_of("workerus2") == "us"


def test_multiregion_trace_properties():
    from repro.workload import MULTIREGION_ZONES, build_trace

    t1 = build_trace("multiregion", duration=60.0, rate=3.0, seed=4)
    t2 = build_trace("multiregion", duration=60.0, rate=3.0, seed=4)
    assert t1 == t2  # deterministic
    assert all(a.zone in dict(MULTIREGION_ZONES) for a in t1)
    assert [a.t for a in t1] == sorted(a.t for a in t1)
    counts = {}
    for a in t1:
        counts[a.zone] = counts.get(a.zone, 0) + 1
    # the configured skew is 3:2:1 — dominant zone strictly busiest
    assert counts["eu"] > counts["us"] > counts["ap"] * 0  # ap may be small
    assert counts["eu"] > counts["ap"]


def test_driver_routes_zone_stamped_arrivals_locally():
    from repro.cluster.simulator import ClusterSim, SimParams
    from repro.cluster.topology import multizone_testbed
    from repro.platform import Platform
    from repro.workload import COMPUTE_S, TraceWorkload, build_trace, \
        register_functions

    sim = ClusterSim(multizone_testbed(ZONES), SimParams(), seed=0)
    register_functions(sim.registry)
    plat = Platform.for_sim(
        sim, "api:\n  workers: *\n  topology: local_first\n"
             "img:\n  workers: *\n  topology: local_first\n"
             "etl:\n  workers: *\n  topology: local_first\n")
    assert plat._sharded
    wl = TraceWorkload(sim, plat.placer(random.Random(1)), COMPUTE_S,
                       script=plat.script)
    wl.load(build_trace("multiregion", duration=20.0, rate=2.0, seed=1))
    sim.run()
    ok = [r for r in wl.records if not r.failed]
    assert ok
    # every record carries its origin stamp and was placed locally (the
    # small cluster never exhausts a zone at this rate)
    assert all(r.origin_zone in ZONES for r in ok)
    local = sum(1 for r in ok if sim.workers[r.worker].zone == r.origin_zone)
    assert local / len(ok) > 0.9
    plat.close()
