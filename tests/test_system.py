"""End-to-end behaviour: dry-run smoke (subprocess, multi-device), and the
benchmark entry points on reduced settings."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


def _run_dryrun(args, devices="8"):
    pytest.importorskip("jax")  # the dry-run subprocess needs a real JAX
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = devices
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=900,
    )


@pytest.mark.parametrize("arch,shape", [
    ("gemma3-4b", "train_4k"),
    ("jamba-1.5-large-398b", "decode_32k"),
    ("qwen3-moe-30b-a3b", "prefill_32k"),
])
def test_dryrun_reduced_single_and_multi(arch, shape, tmp_path):
    r = _run_dryrun(["--arch", arch, "--shape", shape, "--mesh", "both",
                     "--reduced", "--out", str(tmp_path)])
    assert r.returncode == 0, r.stderr[-2000:]
    for mesh in ("single", "multi"):
        rec = json.loads((tmp_path / f"{arch}_{shape}_{mesh}.json").read_text())
        assert rec["status"] == "ok", rec
        assert rec["roofline"]["compute_s"] >= 0
        assert rec["loop_aware"]["flops_per_device"] > 0


def test_dryrun_records_skip(tmp_path):
    r = _run_dryrun(["--arch", "starcoder2-15b", "--shape", "long_500k",
                     "--reduced", "--out", str(tmp_path)])
    assert r.returncode == 0
    rec = json.loads((tmp_path / "starcoder2-15b_long_500k_single.json").read_text())
    assert rec["status"] == "skipped"


def test_overhead_benchmark_claim():
    sys.path.insert(0, str(ROOT))
    from benchmarks import overhead
    ts = overhead._run_one("aAPP", "hello-world", 256, 0.05, n=300)
    ts2 = overhead._run_one("APP", "hello-world", 256, 0.05, n=300)
    import statistics
    gap = abs(statistics.mean(ts) - statistics.mean(ts2))
    assert gap < 1.0  # sub-millisecond (Fig. 8)


def test_scheduler_scale_linearity():
    sys.path.insert(0, str(ROOT))
    from benchmarks.scheduler_scale import _setup
    import time
    from repro.core import parse, try_schedule
    import random
    script = parse("t:\n  workers: *\n  strategy: best_first\n")
    times = {}
    for W in (64, 512):
        st, reg = _setup(W, occupancy=0.3, seed=0)
        reg.register("f", memory=1.0, tag="t")
        conf = st.conf()
        t0 = time.perf_counter()
        for _ in range(50):
            try_schedule("f", conf, script, reg, rng=random.Random(0))
        times[W] = time.perf_counter() - t0
    assert times[512] / times[64] < 8 * 4  # ~linear in W, generous bound
