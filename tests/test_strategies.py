"""The pluggable strategy registry + the new least_loaded / warmest rules.

Acceptance contract: every registered strategy is honoured *identically* by
the scalar Listing-1 reference and the vectorized ``SchedulerSession`` —
hypothesis-property-tested over random scripts / clusters / warmth tables
(plus a seeded hypothesis-free sweep for minimal environments), with
deterministic pin-downs of each rule's semantics.
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import (
    AAppScript,
    Block,
    ClusterState,
    CompiledPolicies,
    Registry,
    SchedulerSession,
    get_strategy,
    parse,
    register_strategy,
    schedule_wave,
    strategy_names,
    try_schedule,
)
from repro.core.scheduler import rejection_reason, valid
from repro.core.strategies import Strategy
from tests.test_batched_equivalence import (
    TAGS,
    clone_state,
    random_cluster,
    random_script,
    random_warmth,
)


# --------------------------------------------------------------------------- #
# registry surface
# --------------------------------------------------------------------------- #


def test_registry_has_the_four_builtins():
    names = strategy_names()
    for n in ("best_first", "any", "least_loaded", "warmest"):
        assert n in names
    assert get_strategy("random") is get_strategy("any")  # paper alias
    assert get_strategy("platform") is get_strategy("best_first")  # APP alias
    assert get_strategy("least-loaded") is get_strategy("least_loaded")


def test_custom_strategy_registers_and_schedules_everywhere():
    """One class + one register_strategy call: the new rule is honoured by
    the parser, the scalar reference, and the session alike."""

    class LastResort(Strategy):
        name = "last_resort"
        narrow_warmth = False

        def select(self, candidates, ctx, rng):
            return candidates[-1]

    register_strategy(LastResort(), "last-resort")
    try:
        script = parse("t:\n  workers: *\n  strategy: last-resort\n")
        assert script["t"].blocks[0].strategy == "last_resort"

        state = ClusterState()
        reg = Registry()
        reg.register("fn", memory=1.0, tag="t")
        for w in ("w0", "w1", "w2"):
            state.add_worker(w, max_memory=10.0)
        assert try_schedule("fn", state.conf(), script, reg) == "w2"
        session = SchedulerSession(state, reg, script)
        assert session.try_schedule("fn") == "w2"
        session.close()
        res = schedule_wave(["fn"], state.conf(),
                            CompiledPolicies(script, reg), reg)
        assert res.assignments == ["w2"]
    finally:
        # the registry is process-global: drop the test strategy again
        from repro.core import strategies as S
        S._REGISTRY.pop("last_resort", None)
        S._ALIASES.pop("last_resort", None)
        S._ALIASES.pop("last-resort", None)


# --------------------------------------------------------------------------- #
# semantics pin-downs
# --------------------------------------------------------------------------- #


def _three_workers(loads=(0, 0, 0)):
    state = ClusterState()
    reg = Registry()
    reg.register("fn", memory=1.0, tag="t")
    reg.register("filler", memory=1.0, tag="x")
    for i, w in enumerate(("w0", "w1", "w2")):
        state.add_worker(w, max_memory=100.0)
        for _ in range(loads[i]):
            state.allocate("filler", w, reg)
    return state, reg


def _script(strategy):
    from repro.core import TagPolicy

    return AAppScript(policies=(
        TagPolicy(tag="t", blocks=(Block(workers=("*",), strategy=strategy),)),))


def test_least_loaded_picks_emptiest_first_on_tie():
    state, reg = _three_workers(loads=(2, 1, 1))
    script = _script("least_loaded")
    # w1 and w2 tie at load 1 -> first in conf order wins
    assert try_schedule("fn", state.conf(), script, reg) == "w1"
    session = SchedulerSession(state, reg, script)
    assert session.try_schedule("fn") == "w1"
    session.close()


def test_least_loaded_ignores_warmth_narrowing():
    """best_first with a warmth source jumps to the warm worker; the
    least_loaded author asked for load, so warmth must not pre-narrow."""
    state, reg = _three_workers(loads=(2, 0, 2))
    warmth = lambda f, w: {"w2": 2}.get(w, 0)
    assert try_schedule("fn", state.conf(), _script("best_first"), reg,
                        warmth=warmth) == "w2"
    assert try_schedule("fn", state.conf(), _script("least_loaded"), reg,
                        warmth=warmth) == "w1"


def test_warmest_prefers_rank_then_load_then_order():
    state, reg = _three_workers(loads=(0, 2, 0))
    script = _script("warmest")
    warmth = lambda f, w: {"w1": 2, "w2": 2}.get(w, 0)
    # w1/w2 tie on rank 2; w2 carries less load
    assert try_schedule("fn", state.conf(), script, reg, warmth=warmth) == "w2"
    session = SchedulerSession(state, reg, script)
    assert session.try_schedule("fn", warmth=warmth) == "w2"
    session.close()
    # without any warmth source all ranks are 0 -> load, then conf order
    assert try_schedule("fn", state.conf(), script, reg) == "w0"


def test_min_cost_weighs_lifecycle_against_congestion():
    """min_cost minimises `LIFECYCLE_S[warmth] + CONGESTION_S x load` — a hot
    but loaded worker can beat a cold idle one, unlike warmest's
    lexicographic (rank, load) order."""
    state, reg = _three_workers(loads=(0, 2, 0))
    script = _script("min_cost")
    # w1 hot (0.0 + 2*0.05 = 0.1) vs w2 warm idle (0.1 + 0 = 0.1): tie ->
    # first in conf order wins (w1); w0 cold idle loses at 0.5
    warmth = lambda f, w: {"w1": 2, "w2": 1}.get(w, 0)
    assert try_schedule("fn", state.conf(), script, reg, warmth=warmth) == "w1"
    session = SchedulerSession(state, reg, script)
    assert session.try_schedule("fn", warmth=warmth) == "w1"
    session.close()
    # no warmth source: every worker is cold, congestion decides -> w0
    assert try_schedule("fn", state.conf(), script, reg) == "w0"
    # eleven invocations of load beat one cold start: warmest would stay on
    # the hot worker, min_cost spills to the cold idle one
    state2, reg2 = _three_workers(loads=(11, 0, 0))
    warmth2 = lambda f, w: {"w0": 2}.get(w, 0)
    assert try_schedule("fn", state2.conf(), _script("warmest"), reg2,
                        warmth=warmth2) == "w0"
    assert try_schedule("fn", state2.conf(), script, reg2,
                        warmth=warmth2) == "w1"


def test_incremental_cost_clamps_warmth_rank():
    from repro.core.strategies import CONGESTION_S, LIFECYCLE_S, \
        incremental_cost

    assert incremental_cost(0, 0) == LIFECYCLE_S[0]
    assert incremental_cost(2, 3) == LIFECYCLE_S[2] + 3 * CONGESTION_S
    assert incremental_cost(-1, 0) == LIFECYCLE_S[0]  # clamped low
    assert incremental_cost(9, 0) == LIFECYCLE_S[2]  # clamped high


def test_min_cost_registers_with_alias():
    names = strategy_names()
    assert "min_cost" in names
    from repro.core import get_strategy
    assert get_strategy("min-cost") is get_strategy("min_cost")
    assert get_strategy("min_cost").narrow_warmth is False


# --------------------------------------------------------------------------- #
# valid() <-> rejection_reason() agreement (the explain-trace twin)
# --------------------------------------------------------------------------- #


def test_rejection_reason_agrees_with_valid():
    for seed in range(40):
        rng = random.Random(seed)
        script = random_script(rng)
        state, reg = random_cluster(rng)
        conf = state.conf()
        for tag in TAGS:
            f = f"fn_{tag}"
            for p in script.policies:
                for b in p.blocks:
                    for w in list(conf) + ["ghost"]:
                        reason = rejection_reason(f, w, conf, reg, b)
                        assert (reason is None) == valid(f, w, conf, reg, b), (
                            seed, f, w, reason)


# --------------------------------------------------------------------------- #
# scalar vs session bit-equality over the new strategies
# --------------------------------------------------------------------------- #

NEW_STRATEGIES = ("least_loaded", "warmest", "min_cost")


def new_strategy_script(rng: random.Random) -> AAppScript:
    """random_script, but every block draws from the new strategy pair (the
    legacy pair is covered by tests/test_batched_equivalence.py)."""
    from repro.core import Affinity, Invalidate, TagPolicy

    policies = []
    for tag in TAGS:
        blocks = []
        for _ in range(rng.randint(1, 3)):
            workers = (("*",) if rng.random() < 0.5 else
                       tuple(rng.sample([f"w{i}" for i in range(8)] + ["ghost"],
                                        rng.randint(1, 4))))
            aff, anti = [], []
            for t in TAGS:
                r = rng.randint(0, 5)
                if r == 0:
                    aff.append(t)
                elif r == 1:
                    anti.append(t)
            blocks.append(Block(
                workers=workers,
                strategy=rng.choice(NEW_STRATEGIES),
                invalidate=Invalidate(
                    capacity_used=rng.choice([None, 40.0, 80.0]),
                    max_concurrent_invocations=rng.choice([None, 1, 4]),
                ),
                affinity=Affinity(affine=tuple(aff), anti_affine=tuple(anti)),
            ))
        policies.append(TagPolicy(tag=tag, blocks=tuple(blocks),
                                  followup=rng.choice(["default", "fail"])))
    return AAppScript(policies=tuple(policies))


def _check_equivalence(seed: int, with_warmth: bool) -> None:
    rng = random.Random(seed)
    script = new_strategy_script(rng)
    state, reg = random_cluster(rng)
    fs = [f"fn_{rng.choice(TAGS)}" for _ in range(rng.randint(1, 12))]
    warmth = random_warmth(rng) if with_warmth else None

    ref_state = clone_state(state, reg)
    ref_rng = random.Random(seed * 7 + 1)
    expected = []
    for f in fs:
        w = try_schedule(f, ref_state.conf(), script, reg, rng=ref_rng,
                         warmth=warmth)
        expected.append(w)
        if w is not None:
            ref_state.allocate(f, w, reg)

    session = SchedulerSession(state, reg, script)
    res = session.schedule_wave(fs, rng=random.Random(seed * 7 + 1),
                                warmth=warmth, apply_to=state)
    session.close()
    assert res.assignments == expected, (
        f"seed={seed} warmth={with_warmth}: {res.assignments} != {expected}")


if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2**16), with_warmth=st.booleans())
    def test_new_strategies_session_equals_scalar_hypothesis(seed, with_warmth):
        _check_equivalence(seed, with_warmth)


def test_new_strategies_session_equals_scalar_seeded_sweep():
    """hypothesis-free fallback for minimal environments."""
    for seed in range(40):
        _check_equivalence(seed, with_warmth=bool(seed % 2))


def test_new_strategies_wave_equals_scalar():
    """The one-shot batched wave honours the new strategies too."""
    for seed in range(30):
        rng = random.Random(seed)
        script = new_strategy_script(rng)
        state, reg = random_cluster(rng)
        fs = [f"fn_{rng.choice(TAGS)}" for _ in range(rng.randint(1, 12))]
        warmth = random_warmth(rng) if seed % 2 else None

        ref_state = clone_state(state, reg)
        ref_rng = random.Random(seed * 7 + 1)
        expected = []
        for f in fs:
            w = try_schedule(f, ref_state.conf(), script, reg, rng=ref_rng,
                             warmth=warmth)
            expected.append(w)
            if w is not None:
                ref_state.allocate(f, w, reg)

        res = schedule_wave(fs, state.conf(), CompiledPolicies(script, reg),
                            reg, rng=random.Random(seed * 7 + 1),
                            warmth=warmth)
        assert res.assignments == expected, (seed, res.assignments, expected)
