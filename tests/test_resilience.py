"""Overload & failure resilience tests (`repro.resilience`).

Covers the admission controller (token-bucket refill/burst, SLO-aware
shedding under backlog pressure, per-tenant counters), the SCFQ fair queue
(weighted drain order, bounded backlogs, requeue-front), retry/backoff
(hedge-once delay ladder, per-tenant retry budgets), the simulator's
worker-failure semantics (conservation of work under kills on both
engines, dead-worker guards, heal re-join), the workload driver's loss
handling (chaos kill -> retry -> completion, honest ``"lost"`` records,
shed records, bounded-queue backpressure), the platform facade's
structured loss records + warm-pool purge, the sharded control plane's
zone drop-out, the stats/Prometheus surfaces, and the invariant the whole
layer hangs on: a disabled bundle is bit-identical in decisions, records
and rng draws to no bundle at all.
"""
import math
import random

import pytest

from repro.cluster.simulator import ClusterSim, SimParams
from repro.cluster.topology import WorkerSpec
from repro.core import (
    ClusterState,
    Registry,
    SchedulerSession,
    ShardedSession,
    parse,
)
from repro.obs import Obs
from repro.obs.slo import SloEngine
from repro.platform import Platform
from repro.pool import StartCosts, WarmPool, make_policy
from repro.resilience import (
    ADMIT,
    DEFAULT_TENANT,
    HEAL_WORKER,
    KILL_WORKER,
    AdmissionController,
    ChaosHarness,
    FairQueue,
    Fault,
    Resilience,
    RetryLedger,
    RetryPolicy,
    SHED_RATE,
    SHED_SLO,
    TenantPolicy,
    TokenBucket,
)
from repro.workload import Arrival, TraceWorkload, overload_trace, poisson_trace

COMPUTE = {"api": 0.25, "etl": 2.0}

DSCRIPT = """
api:
  workers: *
  strategy: least_loaded
etl:
  workers: *
  strategy: least_loaded
"""

PSCRIPT = """
d:
  workers: *
  strategy: best_first
"""


def _sim(workers=None, engine="virtual"):
    topo = workers if workers is not None else {
        "wa": WorkerSpec("wa", "eu", 1, 1024.0),
    }
    sim = ClusterSim(topo, SimParams(), seed=0, engine=engine)
    sim.registry.register("api", memory=128.0, tag="api")
    sim.registry.register("etl", memory=256.0, tag="etl")
    return sim


def _driver(sim, resilience, seed=1):
    plat = Platform.for_sim(sim, DSCRIPT, resilience=resilience)
    return TraceWorkload(sim, plat.placer(random.Random(seed)), COMPUTE,
                         script=plat.script, resilience=resilience)


def _pool():
    return WarmPool(make_policy("fixed_ttl", ttl=100.0),
                    costs=StartCosts(cold=0.5, warm=0.1, hot=0.0),
                    budget_mb=64.0, hot_window=100.0)


# --------------------------------------------------------------------------- #
# admission: token buckets, policies, SLO-aware shed
# --------------------------------------------------------------------------- #


def test_token_bucket_refill_and_burst_cap():
    b = TokenBucket(rate=2.0, burst=4.0)
    assert [b.allow(0.0) for _ in range(5)] == [True] * 4 + [False]
    assert b.allow(0.5)  # 0.5 s * 2/s = one token back
    assert not b.allow(0.5)
    # refill never exceeds the burst depth
    assert [b.allow(100.0) for _ in range(5)] == [True] * 4 + [False]


def test_admission_rate_shed_and_per_tenant_counters():
    adm = AdmissionController({"t": TenantPolicy(rate=1.0, burst=1.0)})
    assert adm.admit("t", "api", 0.01) == (True, ADMIT)
    assert adm.admit("t", "api", 0.02) == (False, SHED_RATE)
    assert adm.admit("t", "api", 0.03) == (False, SHED_RATE)
    # the default policy carries no rate: unknown tenants are never shed
    assert adm.admit("other", "api", 0.03) == (True, ADMIT)
    assert adm.counters["t"] == {"admitted": 1, SHED_RATE: 2, SHED_SLO: 0}
    assert adm.shed == 2 and adm.admitted == 2
    assert list(adm.snapshot()) == ["other", "t"]  # stable key order


def test_admission_slo_shed_only_under_pressure():
    eng = SloEngine({"api": 1.0})
    for i in range(100):  # burn the whole error budget
        eng.observe("api", 0.1 * i, 5.0)
    assert eng.budget_remaining("api") < 0.0
    adm = AdmissionController(slo=eng, budget_floor=0.0, pressure_depth=4)
    assert adm.admit("t", "api", 20.0, queue_depth=4) == (False, SHED_SLO)
    # below the pressure threshold the blown budget does not shed
    assert adm.admit("t", "api", 20.0, queue_depth=3) == (True, ADMIT)
    # functions without an objective never consult the budget
    assert adm.admit("t", "other", 20.0, queue_depth=9) == (True, ADMIT)
    assert adm.counters["t"] == {"admitted": 2, SHED_RATE: 0, SHED_SLO: 1}


def test_tenant_policy_validation():
    for bad in (dict(weight=0.0), dict(rate=0.0), dict(burst=0.0),
                dict(queue_cap=0)):
        with pytest.raises(ValueError):
            TenantPolicy(**bad)


def test_slo_budget_remaining_negative_for_unregistered_function():
    eng = SloEngine({"api": 1.0})
    assert eng.budget_remaining("api") == 1.0  # no traffic: full budget
    with pytest.raises(KeyError, match="no SLO objective"):
        eng.budget_remaining("nope")


# --------------------------------------------------------------------------- #
# fair queue: weighted drain, bounds, requeue
# --------------------------------------------------------------------------- #


def test_fair_queue_weighted_drain_order():
    pols = {"gold": TenantPolicy(weight=2.0), "silver": TenantPolicy()}
    q = FairQueue(lambda t: pols[t])
    for i in range(4):
        assert q.push("gold", f"g{i}", 1.0)
    for i in range(4):
        assert q.push("silver", f"s{i}", 1.0)
    order = []
    while True:
        head = q.pop()
        if head is None:
            break
        order.append(head[0])
    # SCFQ finish tags: weight-2 gold drains twice per silver slot
    assert order == ["gold", "gold", "silver", "gold", "gold",
                     "silver", "silver", "silver"]
    assert q.depth == 0 and q.max_depth == 8


def test_fair_queue_bounded_backlog_and_fifo():
    q = FairQueue(lambda t: TenantPolicy(queue_cap=2))
    assert q.push("t", "a", 1.0)
    assert q.push("t", "b", 1.0)
    assert not q.push("t", "c", 1.0)  # cap reached: caller sheds
    assert q.dropped == {"t": 1} and q.dropped_total == 1
    assert q.depth == 2 and q.depth_of("t") == 2
    assert q.pop()[3] == "a"  # FIFO within a tenant
    assert q.pop()[3] == "b"


def test_fair_queue_requeue_front_preserves_position():
    q = FairQueue(lambda t: TenantPolicy())
    q.push("t", "a", 1.0)
    q.push("t", "b", 1.0)
    tenant, tag, seq, item = q.pop()
    assert item == "a"
    q.requeue_front(tenant, tag, seq, item)
    assert q.depth == 2
    assert q.pop() == (tenant, tag, seq, "a")  # still the head, same tag
    assert q.pop()[3] == "b"


# --------------------------------------------------------------------------- #
# retry: backoff ladder + budgets
# --------------------------------------------------------------------------- #


def test_retry_policy_delay_ladder():
    p = RetryPolicy()  # hedge on
    with pytest.raises(ValueError):
        p.delay(1)  # attempt 1 is the original submission
    assert p.delay(2) == 0.0  # hedge-once: immediate first retry
    assert p.delay(3) == 0.25
    assert p.delay(4) == 0.5
    assert p.delay(10) == 4.0  # capped
    flat = RetryPolicy(hedge=False)
    assert flat.delay(2) == 0.25 and flat.delay(3) == 0.5
    with pytest.raises(ValueError):
        RetryPolicy(base_delay=5.0, max_delay=4.0)
    with pytest.raises(ValueError):
        RetryPolicy(factor=0.5)


def test_retry_ledger_budget():
    led = RetryLedger()
    pol = TenantPolicy()  # retry_budget 0.25, floor of one rescue
    assert led.allowed("t", pol)  # first loss is always worth one retry
    led.note_retry("t")
    assert not led.allowed("t", pol)  # budget max(1, 0.25*0) exhausted
    for _ in range(8):
        led.note_admitted("t")
    assert led.allowed("t", pol)  # budget now max(1, 0.25*8) = 2
    assert led.total_retries == 1


# --------------------------------------------------------------------------- #
# simulator: kill/heal semantics + conservation of work
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("engine", ["virtual", "legacy"])
def test_sim_kill_conserves_work_and_survivors_finish(engine):
    topo = {"w0": WorkerSpec("w0", "eu", 1, 1024.0),
            "w1": WorkerSpec("w1", "eu", 1, 1024.0)}
    sim = ClusterSim(topo, SimParams(), seed=0, engine=engine)
    done = []
    sim.at(0.0, lambda: sim.compute("f", "w0", 1.0, "a1",
                                    lambda: done.append("a1")))
    sim.at(0.0, lambda: sim.compute("f", "w1", 2.0, "a2",
                                    lambda: done.append("a2")))
    sim.at(0.5, lambda: sim.fail_worker("w0"))
    sim.run()
    assert done == ["a2"]  # the dead worker's callback never fires
    assert sim.dead_workers == ("w0",)
    # conservation: delivered + lost == submitted, per worker
    assert sim.delivered_work("w0") == pytest.approx(0.5)
    assert sim.lost_work("w0") == pytest.approx(0.5)
    assert sim.delivered_work("w0") + sim.lost_work("w0") == \
        pytest.approx(sim.submitted_work("w0"))
    assert sim.delivered_work("w1") == pytest.approx(sim.submitted_work("w1"))
    with pytest.raises(RuntimeError, match="failed worker"):
        sim.compute("f", "w0", 1.0, "a3", lambda: None)
    with pytest.raises(KeyError):
        sim.fail_worker("nope")
    sim.heal_worker("w1")  # alive: no-op
    sim.heal_worker("w0")  # healed workers accept work again
    assert sim.dead_workers == ()
    sim.at(sim.now, lambda: sim.compute("f", "w0", 1.0, "a4",
                                        lambda: done.append("a4")))
    sim.run()
    assert done == ["a2", "a4"]


# --------------------------------------------------------------------------- #
# driver: chaos kill -> retry -> completion; honest loss; sheds
# --------------------------------------------------------------------------- #


def test_driver_chaos_kill_retries_lost_work_to_completion():
    sim = _sim()
    res = Resilience.enabled(retry=RetryPolicy())
    wl = _driver(sim, res)
    harness = ChaosHarness([Fault(1.0, KILL_WORKER, "wa"),
                            Fault(2.0, HEAL_WORKER, "wa")])
    harness.arm(wl)
    wl.load([Arrival(t=0.1, function="etl")])
    sim.run()
    assert harness.log == [(1.0, KILL_WORKER, "wa"),
                           (2.0, HEAL_WORKER, "wa")]
    done = [r for r in wl.records if not r.failed]
    assert len(done) == 1 and len(wl.records) == 1
    r = done[0]
    # hedge retry at the kill instant, queued until the heal re-adds
    # capacity, then the full compute replays on the healed worker
    assert r.attempts == 2 and r.worker == "wa"
    assert r.t_submit == pytest.approx(2.0)
    assert r.t_root == pytest.approx(0.1)
    assert r.components["parent_wait"] == pytest.approx(1.9)
    assert wl.permanent_lost == 0 and res.permanent_lost == 0
    assert res.ledger.total_retries == 1
    assert res.snapshot()["retries"] == 1
    # the destroyed first attempt stays on the conservation ledger: the
    # etl ran 0.85 s of its 2.0 before the kill, the remaining 1.15 is lost
    assert sim.lost_work("wa") == pytest.approx(1.15)
    assert sim.delivered_work("wa") + sim.lost_work("wa") == \
        pytest.approx(sim.submitted_work("wa"))


def test_driver_without_retry_writes_honest_lost_record():
    sim = _sim()
    res = Resilience.enabled(retry=None, queue=False)
    wl = _driver(sim, res)
    lost_box = []
    sim.at(1.0, lambda: lost_box.extend(wl.fail_worker("wa")))
    wl.load([Arrival(t=0.1, function="etl", tenant="gold")])
    sim.run()
    assert len(lost_box) == 1
    la = lost_box[0]
    assert (la.function, la.tag, la.worker) == ("etl", "etl", "wa")
    assert la.tenant == "gold"
    assert la.elapsed == pytest.approx(0.9)  # in flight since t=0.1
    [r] = wl.records
    assert r.start_kind == "lost" and r.failed
    assert r.worker == "wa" and r.tenant == "gold" and r.attempts == 1
    assert math.isnan(r.latency)
    assert wl.permanent_lost == 1 and res.permanent_lost == 1
    assert res.snapshot()["permanent_lost"] == 1


def test_driver_without_bundle_still_honours_loss_contract():
    sim = _sim()
    wl = _driver(sim, None)
    sim.at(1.0, lambda: wl.fail_worker("wa"))
    wl.load([Arrival(t=0.1, function="etl")])
    sim.run()
    [r] = wl.records
    assert r.start_kind == "lost" and wl.permanent_lost == 1


def test_driver_admission_shed_records():
    sim = _sim()
    res = Resilience.enabled(
        tenants={"t": TenantPolicy(rate=1.0, burst=1.0)}, retry=None)
    wl = _driver(sim, res)
    wl.load([Arrival(t=0.01 * (i + 1), function="api", tenant="t")
             for i in range(3)])
    sim.run()
    sheds = [r for r in wl.records if r.start_kind == "shed"]
    assert len(sheds) == 2  # one token in the bucket, ~no refill in 20 ms
    for r in sheds:
        assert r.worker == "<shed>" and r.failed and r.tenant == "t"
        assert math.isnan(r.latency)
    assert res.admission.counters["t"] == \
        {"admitted": 1, SHED_RATE: 2, SHED_SLO: 0}
    assert res.snapshot()["shed"] == 2
    done = [r for r in wl.records if not r.failed]
    assert len(done) == 1 and done[0].tenant == "t"


def test_driver_bounded_queue_sheds_instead_of_growing():
    # a cluster nothing fits on: admitted work parks in the fair queue and
    # the tenant's bounded backlog sheds the overflow (no failure records,
    # no unbounded heap)
    sim = _sim({"wa": WorkerSpec("wa", "eu", 1, 64.0)})  # api needs 128 MB
    res = Resilience.enabled(tenants={"t": TenantPolicy(queue_cap=1)},
                             retry=None)
    wl = _driver(sim, res)
    wl.load([Arrival(t=0.01, function="api", tenant="t"),
             Arrival(t=0.02, function="api", tenant="t")])
    sim.run()
    assert res.queue_shed == 1
    assert res.queue.depth == 1 and res.queue.depth_of("t") == 1
    assert res.queue.dropped == {"t": 1}
    snap = res.snapshot()
    assert snap["shed"] == 1 and snap["queue_shed"] == 1
    assert snap["queue_depth"] == 1
    assert [r.start_kind for r in wl.records] == ["shed"]
    assert sim.failures == []  # backpressure, not unschedulable failures


# --------------------------------------------------------------------------- #
# platform facade: structured loss + pool purge
# --------------------------------------------------------------------------- #


def test_platform_fail_worker_structured_loss_and_pool_purge():
    tnow = [0.0]
    pool = _pool()
    res = Resilience.enabled(retry=None, queue=False)
    plat = Platform.from_yaml(PSCRIPT, cluster={"w0": 8.0}, pool=pool,
                              resilience=res, clock=lambda: tnow[0])
    plat.register("divide", memory=1.0, tag="d")
    a = plat.invoke("divide", tenant="gold")
    tnow[0] = 1.0
    plat.complete(a)  # parks an idle container on w0
    tnow[0] = 2.5
    b = plat.invoke("divide", tenant="silver")  # in flight at kill time
    c = plat.invoke("divide")
    tnow[0] = 3.0
    plat.complete(c)  # a second idle container on w0
    tnow[0] = 4.0
    lost = plat.fail_worker("w0")
    [la] = lost  # only the in-flight activation is lost
    assert la.activation_id == b.activation_id
    assert (la.function, la.tag, la.worker) == ("divide", "d", "w0")
    assert la.tenant == "silver"
    assert la.elapsed == pytest.approx(1.5)  # invoked at 2.5, killed at 4.0
    assert plat.lost_activations == 1
    assert "w0" not in plat.workers()
    # the busy container is destroyed, the idle ones drained
    assert pool.busy_counts() == {}
    assert pool.residency_counts() == {}
    assert plat.stats()["lost_activations"] == 1


def test_platform_fail_worker_defaults_without_bundle():
    plat = Platform.from_yaml(PSCRIPT, cluster={"w0": 8.0})
    plat.register("divide", memory=1.0, tag="d")
    d = plat.invoke("divide")
    [la] = plat.fail_worker("w0")
    assert la.activation_id == d.activation_id
    assert la.tenant == DEFAULT_TENANT and la.elapsed == 0.0


# --------------------------------------------------------------------------- #
# sharded control plane: zone drop-out
# --------------------------------------------------------------------------- #


def test_sharded_session_zone_dropout():
    script = parse("t:\n  workers: *\n  strategy: best_first\n")
    state = ClusterState()
    reg = Registry()
    reg.register("fn", memory=1.0, tag="t")
    for w, z in (("e0", "eu"), ("e1", "eu"), ("u0", "us")):
        state.add_worker(w, max_memory=8.0, zone=z)
    sharded = ShardedSession(state, reg, script)
    assert state.zones() == ("eu", "us")
    assert sharded.try_schedule("fn", rng=random.Random(0)) is not None
    for w in ("e0", "e1"):
        state.fail_worker(w)
    # the zone vanishes from the alive set and the router stops offering it
    assert state.zones() == ("us",)
    got = sharded.try_schedule("fn", rng=random.Random(1))
    assert got == "u0"
    flat = SchedulerSession(state, reg, script)
    assert flat.try_schedule("fn", rng=random.Random(1)) == got


# --------------------------------------------------------------------------- #
# chaos schedule plumbing
# --------------------------------------------------------------------------- #


def test_fault_validation_and_sorted_schedule():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(1.0, "explode", "w0")
    h = ChaosHarness([Fault(2.0, HEAL_WORKER, "wa"),
                      Fault(1.0, KILL_WORKER, "wa")])
    assert [f.t for f in h.faults] == [1.0, 2.0]


# --------------------------------------------------------------------------- #
# stats / Prometheus surfaces
# --------------------------------------------------------------------------- #


def test_platform_stats_and_prometheus_expose_resilience():
    obs = Obs.enabled(verdicts=False, timers=False)
    res = Resilience.enabled(tenants={"gold": TenantPolicy(rate=5.0)})
    plat = Platform.from_yaml(PSCRIPT, cluster={"w0": 8.0},
                              obs=obs, resilience=res)
    plat.register("divide", memory=1.0, tag="d")
    plat.invoke("divide", tenant="gold")
    r = plat.stats()["resilience"]
    assert r["shed"] == 0 and r["retries"] == 0 and r["permanent_lost"] == 0
    assert r["admitted"] == 1
    assert r["tenants"]["gold"]["admitted"] == 1
    text = obs.registry.render()
    assert "resilience_shed 0" in text
    assert "resilience_queue_depth 0" in text
    assert "resilience_tenants_gold_admitted 1" in text


def test_disabled_bundle_is_inert():
    res = Resilience()
    assert not res.active
    snap = res.snapshot()
    assert snap["shed"] == 0 and "tenants" not in snap
    plat = Platform.from_yaml(PSCRIPT, cluster={"w0": 8.0}, resilience=res)
    plat.register("divide", memory=1.0, tag="d")
    assert plat.invoke("divide", tenant="gold").worker == "w0"
    assert "resilience" not in plat.stats()  # no dead keys in stats


# --------------------------------------------------------------------------- #
# the zero-overhead contract: disabled == absent, bit for bit
# --------------------------------------------------------------------------- #

BSCRIPT = """
d:
  workers: *
  strategy: random
i:
  workers: *
  strategy: random
  affinity: [d]
"""


def _facade_fingerprint(resilience):
    plat = Platform.from_yaml(BSCRIPT,
                              cluster={"w0": 8.0, "w1": 8.0, "w2": 8.0},
                              pool=_pool(), resilience=resilience)
    plat.register("divide", memory=1.0, tag="d")
    plat.register("impera", memory=1.0, tag="i")
    rng = random.Random(7)
    mix = random.Random(11)
    out = []
    for _ in range(40):
        f = mix.choice(["divide", "impera"])
        d = plat.invoke(f, rng, tenant=mix.choice([None, "gold"]))
        out.append((f, d.worker, d.start_kind))
        if d.worker is not None:
            plat.complete(d)
    # the rng's post-run stream is part of the fingerprint: the disabled
    # layer must consume exactly the same draws as no layer at all
    return out, [rng.random() for _ in range(3)]


def test_disabled_resilience_is_bit_identical_on_the_facade():
    assert _facade_fingerprint(None) == _facade_fingerprint(Resilience())


RSCRIPT = """
api:
  workers: *
  strategy: random
etl:
  workers: *
  strategy: random
  affinity: [api]
"""


def _driver_fingerprint(resilience):
    sim = _sim({"wa": WorkerSpec("wa", "eu", 1, 1024.0),
                "wb": WorkerSpec("wb", "eu", 1, 1024.0)})
    plat = Platform.for_sim(sim, RSCRIPT, resilience=resilience)
    rng = random.Random(5)
    wl = TraceWorkload(sim, plat.placer(rng), COMPUTE,
                       script=plat.script, resilience=resilience)
    trace = poisson_trace(3.0, 10.0, [("api", 2.0), ("etl", 1.0)],
                          random.Random(9))
    wl.load(trace)
    sim.run()
    # repr() keeps NaN-latency failure records comparable
    return [repr(r) for r in wl.records], [rng.random() for _ in range(3)]


def test_disabled_resilience_is_bit_identical_in_the_driver():
    assert _driver_fingerprint(None) == _driver_fingerprint(Resilience())


# --------------------------------------------------------------------------- #
# overload trace generator
# --------------------------------------------------------------------------- #


def test_overload_trace_tenants_and_determinism():
    rates = [("gold", 5.0), ("silver", 2.0), ("idle", 0.0)]
    fns = [("api", 1.0)]
    t1 = overload_trace(rates, 20.0, fns, random.Random(4))
    t2 = overload_trace(rates, 20.0, fns, random.Random(4))
    assert t1 == t2  # same rng stream, same trace
    assert {a.tenant for a in t1} == {"gold", "silver"}  # zero-rate skipped
    assert all(0.0 <= a.t < 20.0 for a in t1)
    assert all(t1[i].t <= t1[i + 1].t for i in range(len(t1) - 1))
    gold = sum(1 for a in t1 if a.tenant == "gold")
    assert gold > len(t1) - gold  # the 5 rps stream dominates the 2 rps one
