"""aAPP parser: the paper's example scripts, round-trips, static errors."""
import pytest

from repro.core import parse, to_text
from repro.core.ast import AAppError

FIG3 = """
f_tag:
  - workers:
      - local_w1
      - local_w2
    strategy: best_first
    invalidate:
      - capacity_used 80%
    affinity: g_tag, !h_tag
  - workers:
      - public_w1
  - followup: fail
"""

FIG5 = """
d:
  workers: *
  strategy: random
  affinity:
    - !h_eu
    - !h_us
i:
  workers: *
  strategy: random
  affinity:
    - !h_eu
    - !h_us
    - d
h_eu:
  workers:
    - workereu1
h_us:
  workers:
    - workerus1
"""


def test_fig3_structure():
    s = parse(FIG3)
    p = s["f_tag"]
    assert p.followup == "fail"
    assert len(p.blocks) == 2
    b0 = p.blocks[0]
    assert b0.workers == ("local_w1", "local_w2")
    assert b0.strategy == "best_first"
    assert b0.invalidate.capacity_used == 80.0
    assert b0.affinity.affine == ("g_tag",)
    assert b0.affinity.anti_affine == ("h_tag",)
    assert p.blocks[1].workers == ("public_w1",)


def test_fig5_structure():
    s = parse(FIG5)
    assert s.tags == ("d", "i", "h_eu", "h_us")
    assert s["d"].blocks[0].is_wildcard
    assert s["d"].blocks[0].strategy == "any"  # 'random' alias
    assert s["i"].blocks[0].affinity.affine == ("d",)
    assert set(s["i"].blocks[0].affinity.anti_affine) == {"h_eu", "h_us"}
    assert s["h_eu"].blocks[0].workers == ("workereu1",)


@pytest.mark.parametrize("script", [FIG3, FIG5])
def test_roundtrip(script):
    s = parse(script)
    assert parse(to_text(s)) == s


def test_max_concurrent_invocations():
    s = parse("t:\n  workers: *\n  invalidate:\n    - max_concurrent_invocations 5\n")
    assert s["t"].blocks[0].invalidate.max_concurrent_invocations == 5


@pytest.mark.parametrize("bad", [
    "",  # empty
    "t: 17",  # not a mapping/sequence
    "t:\n  workers: *\n  strategy: bogus\n",
    "t:\n  strategy: any\n",  # no workers
    "t:\n  workers: *\n  invalidate:\n    - capacity_used 150%\n",
    "t:\n  workers: *\n  invalidate:\n    - frobnicate 3\n",
    "t:\n  workers: *\n  affinity: [x, !x]\n",  # unsatisfiable
    "t:\n  workers: *\n  followup: maybe\n",
    "t:\n  workers: [w1, '*']\n",  # wildcard mixed with ids
])
def test_static_errors(bad):
    with pytest.raises(AAppError):
        parse(bad)


def test_inline_affinity_unquoting():
    s = parse("t:\n  workers: *\n  affinity: a, !b, c\n")
    a = s["t"].blocks[0].affinity
    assert a.affine == ("a", "c") and a.anti_affine == ("b",)


# ---- AAppScript.to_yaml round trips ---------------------------------------- #

RICH = """
f_tag:
  - workers:
      - local_w1
      - local_w2
    strategy: least_loaded
    invalidate:
      - capacity_used 80%
      - max_concurrent_invocations 3
    affinity: g_tag, !h_tag
  - workers: *
    strategy: warmest
  - followup: fail
g_tag:
  workers: *
  strategy: random
"""


@pytest.mark.parametrize("script", [FIG3, FIG5, RICH])
@pytest.mark.parametrize("stylised", [False, True])
def test_to_yaml_roundtrip(script, stylised):
    s = parse(script)
    text = s.to_yaml(stylised=stylised)
    assert parse(text) == s


def test_to_yaml_stylised_forms():
    """stylised=True emits the paper's presentation: bare `*` and `!tag`."""
    s = parse(FIG5)
    text = s.to_yaml(stylised=True)
    assert "workers: *" in text
    assert "- !h_eu" in text
    assert '"' not in text  # nothing needed quoting
    strict = s.to_yaml()
    assert 'workers: "*"' in strict
    assert '- "!h_eu"' in strict
    assert parse(text) == parse(strict) == s


def test_to_yaml_preserves_strategies_and_followup():
    s = parse(RICH)
    s2 = parse(s.to_yaml())
    assert s2["f_tag"].followup == "fail"
    assert s2["f_tag"].blocks[0].strategy == "least_loaded"
    assert s2["f_tag"].blocks[1].strategy == "warmest"
    assert s2["g_tag"].blocks[0].strategy == "any"  # 'random' normalised
    inv = s2["f_tag"].blocks[0].invalidate
    assert inv.capacity_used == 80.0 and inv.max_concurrent_invocations == 3


def test_new_strategies_parse_with_aliases():
    s = parse("t:\n  workers: *\n  strategy: least-loaded\n")
    assert s["t"].blocks[0].strategy == "least_loaded"
    with pytest.raises(AAppError):
        parse("t:\n  workers: *\n  strategy: hottest\n")


# --------------------------------------------------------------------------- #
# v3 topology terms: zone:/!zone: affinity + the per-block topology hint
# --------------------------------------------------------------------------- #

ZONED = """
d:
  workers: *
  strategy: best_first
  topology: local_first
  affinity: [x, zone:eu, !y, !zone:us]
i:
  - workers:
      - w1
      - w2
    topology: least_loaded_zone
    affinity:
      - zone:ap
  - followup: fail
"""


def test_zone_terms_parse_into_affinity_fields():
    s = parse(ZONED)
    a = s["d"].blocks[0].affinity
    assert a.affine == ("x",)
    assert a.anti_affine == ("y",)
    assert a.zones == ("eu",)
    assert a.anti_zones == ("us",)
    assert not a.empty and not a.zone_free
    assert s["d"].blocks[0].topology == "local_first"
    assert s["i"].blocks[0].topology == "least_loaded_zone"
    assert s["i"].blocks[0].affinity.zones == ("ap",)
    assert s["i"].followup == "fail"


@pytest.mark.parametrize("stylised", [False, True])
def test_zone_terms_roundtrip(stylised):
    s = parse(ZONED)
    text = s.to_yaml(stylised=stylised)
    assert parse(text) == s
    # and a second trip is a fixed point
    assert parse(parse(text).to_yaml(stylised=stylised)) == s


def test_zone_terms_stylised_bare_forms():
    s = parse(ZONED)
    text = s.to_yaml(stylised=True)
    assert "- zone:eu" in text
    assert "- !zone:us" in text  # the bare bang form survives
    assert '"' not in text
    strict = s.to_yaml()
    assert '- "!zone:us"' in strict
    assert parse(strict) == s


def test_inline_bare_bang_zone_term():
    # the pre-processor must quote `!zone:us` inside flow lists too
    s = parse("t:\n  workers: *\n  affinity: [!zone:us, zone:eu]\n")
    a = s["t"].blocks[0].affinity
    assert a.anti_zones == ("us",) and a.zones == ("eu",)


def test_topology_hint_validation():
    s = parse("t:\n  workers: *\n  topology: local-first\n")  # alias
    assert s["t"].blocks[0].topology == "local_first"
    with pytest.raises(AAppError):
        parse("t:\n  workers: *\n  topology: nearest_star\n")


def test_zone_unsatisfiable_is_a_parse_error():
    with pytest.raises(AAppError):
        parse("t:\n  workers: *\n  affinity: [zone:eu, !zone:eu]\n")
    with pytest.raises(AAppError):
        parse("t:\n  workers: *\n  affinity: [zone:eu, zone:us]\n")


def test_zone_terms_never_enter_the_tag_universe():
    from repro.core import Registry, compile_script

    reg = Registry()
    reg.register("f", memory=1.0, tag="d")
    compiled = compile_script(parse(ZONED), reg)
    assert not any(t.startswith("zone:") for t in compiled.tag_index.tags)


# ---- v4 cost clause --------------------------------------------------------- #

COSTED = """
d:
  workers: *
  strategy: best_first
  cost:
    - budget 1.5s
    - rate 1.66e-05 $/GB-s
i:
  - workers: *
    strategy: min_cost
    affinity: [d]
    cost:
      - budget 3.5s
  - followup: fail
"""


def test_cost_clause_parses_bare_block_and_list_forms():
    s = parse(COSTED)
    c = s["d"].blocks[0].cost  # bare single-block mapping form
    assert c.budget_s == 1.5 and c.rate_per_gb_s == 1.66e-05
    c = s["i"].blocks[0].cost  # explicit block-list form
    assert c.budget_s == 3.5 and c.rate_per_gb_s is None
    # inline string form, unit suffixes optional
    s = parse("t:\n  workers: *\n  cost: budget 2\n")
    assert s["t"].blocks[0].cost.budget_s == 2.0


@pytest.mark.parametrize("stylised", [False, True])
def test_cost_clause_roundtrips(stylised):
    s = parse(COSTED)
    text = s.to_yaml(stylised=stylised)
    assert "cost:" in text and "budget 1.5s" in text
    assert parse(text) == s
    # and the emitted text is itself a fixed point
    assert parse(parse(text).to_yaml(stylised=stylised)) == s


@pytest.mark.parametrize("bad", [
    "t:\n  workers: *\n  cost: []\n",                      # empty clause
    "t:\n  workers: *\n  cost:\n    - budget 1s\n    - budget 2s\n",
    "t:\n  workers: *\n  cost:\n    - rate 1\n    - rate 2\n",
    "t:\n  workers: *\n  cost:\n    - 1.5\n",              # bare number
    "t:\n  workers: *\n  cost:\n    - budget -1s\n",       # non-positive
    "t:\n  workers: *\n  cost:\n    - speed 9\n",          # unknown option
])
def test_cost_clause_static_errors(bad):
    with pytest.raises(AAppError):
        parse(bad)
