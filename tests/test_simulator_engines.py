"""The two processor-sharing cores of :class:`ClusterSim`.

* **agreement** — the O(log n) virtual-time core and the legacy full-scan
  core produce identical invocation records (function, worker, start kind)
  and latencies equal to float noise on every workload scenario;
* **conservation** — per-worker delivered cpu-seconds equal submitted task
  work on both cores (the lazy advancement bookkeeping is exact);
* **event economy** — the virtual core schedules no more completion events
  than the legacy core, and the legacy core's stale-ETA token fix keeps the
  completion-event count linear in the task count (the pre-fix code let a
  stale event re-enter ``_reschedule_completions`` and push a duplicate
  event for the same task — a churn cascade);
* **session locality keying** — ``db_connect`` charges per
  *(worker, replica zone)*, not per worker.
"""
import random

import pytest

from repro.cluster.simulator import ClusterSim, SimParams
from repro.cluster.topology import paper_testbed
from repro.core import SchedulerSession, parse
from repro.workload import (
    COMPUTE_S,
    SCENARIOS,
    TraceWorkload,
    build_trace,
    register_functions,
)

SCRIPT = """
api:
  workers: *
  strategy: random
img:
  workers: *
  strategy: random
etl:
  workers: *
  strategy: random
d:
  workers: *
  strategy: random
i:
  workers: *
  strategy: random
  affinity: [d]
"""


def _run_trace(scenario: str, engine: str, *, duration=40.0, rate=2.0, seed=0):
    sim = ClusterSim(paper_testbed(), SimParams(), seed=seed, engine=engine)
    register_functions(sim.registry)
    script = parse(SCRIPT)
    rng = random.Random(seed + 1)
    session = SchedulerSession(sim.state, sim.registry, script,
                               clock=lambda: sim.now)
    wl = TraceWorkload(sim, lambda f: session.try_schedule(f, rng=rng),
                       COMPUTE_S, script=script)
    wl.load(build_trace(scenario, duration=duration, rate=rate, seed=seed))
    sim.run()
    return sim, wl


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_engines_agree_on_every_scenario(scenario):
    sims = {e: _run_trace(scenario, e) for e in ("legacy", "virtual")}
    (lg_sim, lg_wl), (vt_sim, vt_wl) = sims["legacy"], sims["virtual"]
    assert [(r.function, r.worker, r.start_kind) for r in lg_wl.records] == \
           [(r.function, r.worker, r.start_kind) for r in vt_wl.records]
    for a, b in zip(lg_wl.records, vt_wl.records):
        assert a.latency == pytest.approx(b.latency, abs=1e-9)
    # satellite: event counts drop under the virtual core (per-worker token
    # arming vs a global re-arm on every membership change)
    assert (vt_sim.stats["completion_pushes"]
            <= lg_sim.stats["completion_pushes"])


@pytest.mark.parametrize("engine", ["legacy", "virtual"])
@pytest.mark.parametrize("scenario", SCENARIOS)
def test_conservation_of_work(scenario, engine):
    """Total compute delivered per worker == total task work submitted."""
    sim, wl = _run_trace(scenario, engine)
    assert not sim.has_compute()
    total_sub = 0.0
    for w in sim.workers:
        d, s = sim.delivered_work(w), sim.submitted_work(w)
        total_sub += s
        assert d == pytest.approx(s, rel=1e-9, abs=1e-9), (w, d, s)
    assert total_sub > 0.0  # the trace actually exercised the cores


@pytest.mark.parametrize("engine", ["legacy", "virtual"])
def test_completion_event_churn_is_linear(engine):
    """Pin the stale-ETA-token fix: staggered arrivals on one shared worker
    repeatedly change rates, which in the pre-fix legacy core made every
    stale event re-push a duplicate completion for the same earliest task.
    With the token guard, completion pushes stay <= one per rate change
    (task add / task finish / float under-run)."""
    workers = {k: v for k, v in paper_testbed().items() if k == "workereu2"}
    sim = ClusterSim(workers, SimParams(), seed=0, engine=engine)
    N = 40
    done = []
    for i in range(N):
        sim.at(0.1 * i, lambda i=i: sim.compute(
            "api", "workereu2", 1.0, f"a{i}", lambda i=i: done.append(i)))
    sim.run()
    assert len(done) == N
    pushes = sim.stats["completion_pushes"]
    assert pushes <= 2 * N + 5, (engine, sim.stats)
    # stale drops happen (rates changed) but never re-arm a duplicate
    assert sim.stats["stale_completions"] <= pushes


def test_virtual_core_batches_equal_finishes():
    """Tasks finishing at the same virtual instant complete in one event,
    in submission order."""
    workers = {k: v for k, v in paper_testbed().items() if k == "workereu2"}
    sim = ClusterSim(workers, SimParams(), seed=0, engine="virtual")
    order = []
    for i in range(4):
        sim.compute("api", "workereu2", 1.0, f"a{i}",
                    lambda i=i: order.append(i))
    sim.run()
    assert order == [0, 1, 2, 3]
    # 2 vCPUs, 4 equal tasks of 1 cpu-second: all finish at t = 2.0
    assert sim.now == pytest.approx(2.0)


def test_db_connect_keys_by_replica_zone():
    """§II session locality: one session per (worker, replica).  The worker's
    first connection to each replica pays conn_setup; reuse is free; another
    worker shares nothing."""
    sim = ClusterSim(paper_testbed(), SimParams(), seed=0)
    p = sim.p
    assert sim.db_connect("workereu2") == p.conn_setup  # local (eu) replica
    assert sim.db_connect("workereu2") == 0.0  # session reuse
    assert sim.db_connect("workereu2", "us") == p.conn_setup  # other replica
    assert sim.db_connect("workereu2", "us") == 0.0
    assert sim.db_connect("workereu2", "eu") == 0.0  # still the same session
    assert sim.db_connect("workereu3") == p.conn_setup  # per worker


def test_small_node_pressure_counter_matches_scan():
    """The O(1) pressure counter equals a recomputed scan at every event."""
    sim = ClusterSim(paper_testbed(), SimParams(), seed=0, engine="virtual")

    def scan():
        n = 0
        for w, vw in sim._vw.items():
            if sim.workers[w].vcpus <= 1:
                n += sum(1 for (_vf, _id, t) in vw.heap
                         if not t.fname.startswith("heavy"))
        return n

    checks = []
    for i, (w, fn) in enumerate([("workereu1", "api"), ("workereu1", "heavy_x"),
                                 ("workereu2", "api"), ("workerus1", "etl")]):
        sim.at(0.05 * i, lambda w=w, fn=fn: (
            sim.compute(fn, w, 0.5, f"p{w}{fn}", lambda: None),
            checks.append(sim._small_node_pressure() == scan())))
    sim.run()
    assert checks and all(checks)
    assert sim._small_node_pressure() == 0
