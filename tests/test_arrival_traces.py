"""Trace generators (`repro.workload.traces`): determinism, time-sortedness,
rate bounds, and DAG-children round-trips through the driver."""
import math
import random

import pytest

from repro.cluster.simulator import ClusterSim, SimParams
from repro.cluster.topology import paper_testbed
from repro.core import parse, try_schedule
from repro.workload import (
    COMPUTE_S,
    SCENARIOS,
    TraceWorkload,
    build_trace,
    register_functions,
)
from repro.workload.traces import chained_trace, diurnal_trace


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_same_seed_same_trace(scenario):
    a = build_trace(scenario, duration=60.0, rate=2.0, seed=7)
    b = build_trace(scenario, duration=60.0, rate=2.0, seed=7)
    assert a == b
    c = build_trace(scenario, duration=60.0, rate=2.0, seed=8)
    assert a != c  # a different seed produces a different trace


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_traces_are_time_sorted_within_duration(scenario):
    trace = build_trace(scenario, duration=60.0, rate=2.0, seed=3)
    assert trace, "empty trace"
    times = [a.t for a in trace]
    assert times == sorted(times)
    assert 0.0 <= times[0] and times[-1] < 60.0


def test_diurnal_rate_stays_within_base_and_peak():
    base, peak, duration, period = 1.0, 6.0, 4000.0, 100.0
    trace = diurnal_trace(base, peak, duration, [("f", 1.0)],
                          random.Random(0), period=period)
    # empirical rate over each quarter-period window stays within the
    # modulation envelope [base, peak] (3-sigma Poisson slack)
    win = period / 4.0
    for k in range(int(duration / win)):
        n = sum(1 for a in trace if k * win <= a.t < (k + 1) * win)
        lo = base * win - 3.0 * math.sqrt(base * win)
        hi = peak * win + 3.0 * math.sqrt(peak * win)
        assert lo <= n <= hi, f"window {k}: {n} outside [{lo:.1f}, {hi:.1f}]"
    # and the modulation is real: peak windows see far more than troughs
    on = sum(1 for a in trace if (a.t % period) < period / 2.0)
    off = len(trace) - on
    assert on > 1.5 * off


def test_chained_children_round_trip_through_driver():
    trace = chained_trace(1.0, 30.0, random.Random(5), parent="divide",
                          children=(("impera", 2),))
    assert all(a.function == "divide" and a.children == (("impera", 2),)
               for a in trace)
    sim = ClusterSim(paper_testbed(), SimParams(), seed=0)
    register_functions(sim.registry)
    script = parse("default:\n  workers: *\n  strategy: random\n")
    rng = random.Random(0)
    wl = TraceWorkload(
        sim,
        lambda f: try_schedule(f, sim.state.conf(), script, sim.registry,
                               rng=rng),
        COMPUTE_S, script=script)
    wl.load(trace)
    sim.run()
    ok = [r for r in wl.records if not r.failed]
    divides = [r for r in ok if r.function == "divide"]
    imperas = [r for r in ok if r.function == "impera"]
    # every declared child was spawned exactly once, after its parent
    assert len(divides) == len(trace)
    assert len(imperas) == 2 * len(divides)
    assert len(ok) == len(wl.records)  # nothing unschedulable
    # children spawn when a parent's compute finishes, never before the
    # earliest possible parent completion
    first_divide_done = min(r.t_submit + r.latency for r in divides)
    assert min(r.t_submit for r in imperas) >= first_divide_done
