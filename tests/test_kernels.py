"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs ref.py."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.affinity import affinity_valid
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.mamba_scan import selective_scan, selective_scan_ref

# --------------------------------------------------------------------------- #
# affinity
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("W,T,F", [(1, 1, 1), (7, 3, 5), (37, 19, 23),
                                   (128, 128, 128), (130, 5, 257)])
def test_affinity_kernel_matches_ref(W, T, F):
    rng = np.random.default_rng(W * 1000 + T * 10 + F)
    occ = rng.integers(0, 3, (W, T)).astype(np.int32)
    aff = rng.integers(-1, 2, (F, T)).astype(np.int8)
    wmask = rng.random((F, W)) > 0.2
    mem_used = (rng.random(W) * 100).astype(np.float32)
    max_mem = np.full(W, 120, np.float32)
    n_funcs = occ.sum(1).astype(np.int32)
    f_mem = (rng.random(F) * 30).astype(np.float32)
    cap = np.where(rng.random(F) > 0.5, 80.0, 1e9).astype(np.float32)
    conc = np.where(rng.random(F) > 0.5, 10, 2**30).astype(np.int32)
    args = (occ, aff, wmask, mem_used, max_mem, n_funcs, f_mem, cap, conc)
    ref = np.asarray(affinity_valid(*args, backend="ref"))
    out = np.asarray(affinity_valid(*args, backend="pallas"))
    np.testing.assert_array_equal(ref, out)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(1, 12), st.integers(1, 40),
       st.integers(0, 2**31 - 1))
def test_affinity_kernel_property(W, T, F, seed):
    rng = np.random.default_rng(seed)
    occ = rng.integers(0, 2, (W, T)).astype(np.int32)
    aff = rng.integers(-1, 2, (F, T)).astype(np.int8)
    wmask = np.ones((F, W), bool)
    mem_used = np.zeros(W, np.float32)
    max_mem = np.ones(W, np.float32)
    n_funcs = np.zeros(W, np.int32)
    f_mem = np.zeros(F, np.float32)
    out = np.asarray(affinity_valid(occ, aff, wmask, mem_used, max_mem, n_funcs,
                                    f_mem, backend="pallas"))
    # brute-force oracle
    for f in range(F):
        for w in range(W):
            ok = True
            for t in range(T):
                if aff[f, t] == 1 and occ[w, t] == 0:
                    ok = False
                if aff[f, t] == -1 and occ[w, t] > 0:
                    ok = False
            assert out[f, w] == ok


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,Sq,Skv,H,K,hd,causal,window,dt,tol", [
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32, 2e-5),
    (1, 256, 256, 8, 8, 32, True, 64, jnp.float32, 2e-5),
    (2, 200, 200, 4, 1, 64, True, None, jnp.bfloat16, 5e-2),
    (1, 128, 384, 4, 2, 64, False, None, jnp.float32, 2e-5),
    (1, 384, 384, 2, 2, 128, True, 100, jnp.float32, 2e-5),
])
def test_flash_attention_sweep(B, Sq, Skv, H, K, hd, causal, window, dt, tol):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dt)
    k = jax.random.normal(ks[1], (B, Skv, K, hd), dt)
    v = jax.random.normal(ks[2], (B, Skv, K, hd), dt)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=128, bk=128)
    err = np.max(np.abs(np.asarray(ref, np.float32) - np.asarray(out, np.float32)))
    assert err < tol, err


# --------------------------------------------------------------------------- #
# mamba selective scan
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,S,D,N,chunk,bd", [
    (2, 64, 32, 4, 16, 16), (1, 100, 48, 16, 32, 16), (2, 128, 64, 8, 64, 64),
    (1, 48, 16, 2, 48, 16),
])
def test_mamba_scan_sweep(B, S, D, N, chunk, bd):
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, D))).astype(jnp.float32) * 0.1
    x = jax.random.normal(ks[1], (B, S, D), jnp.float32)
    b = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    c = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[4], (D, N), jnp.float32))
    ref = selective_scan_ref(dt, x, b, c, a)
    out = selective_scan(dt, x, b, c, a, chunk=chunk, bd=bd)
    assert float(jnp.max(jnp.abs(ref - out))) < 1e-4
